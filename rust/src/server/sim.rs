//! Virtual-time full-system simulation driver.
//!
//! A discrete-event loop composes the paper's architecture end to end:
//! user tasks arrive (bursty trace), each workflow emits its stages as LLM
//! requests into the central queue, the active [`SchedulePolicy`] picks the
//! next request, the active [`DispatchPolicy`] places it on an engine
//! instance, engines run continuous-batching iterations under the
//! calibrated cost model, and completions feed the orchestrator, whose
//! profiles in turn drive Kairos' scheduler/dispatcher refreshes.

use std::collections::HashMap;

use crate::agents::apps::WorkflowPlan;
use crate::dispatch::DispatchPolicy;
use crate::engine::core::{EngineConfig, EngineCore, SimBackend, StepOutcome};
use crate::engine::cost_model::{CostModel, ModelKind};
use crate::engine::request::{Request, RequestId};
use crate::lb::policies::SchedulePolicy;
use crate::lb::queue::RequestQueue;
use crate::metrics::{MetricsCollector, RequestRecord, RunSummary, WorkflowRecord};
use crate::orchestrator::graph::ExecRecord;
use crate::orchestrator::ids::{AgentId, MsgId};
use crate::orchestrator::Orchestrator;
use crate::simcore::EventQueue;
use crate::workload::ArrivalEvent;
use crate::Time;

/// Simulation configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub n_instances: usize,
    pub model: ModelKind,
    pub block_size: u32,
    /// vLLM max_num_seqs per instance.
    pub max_batch: usize,
    /// Priority/profile refresh period (paper §7.7: fixed intervals,
    /// asynchronous).
    pub refresh_interval: f64,
    /// Fraction of the trace treated as warmup (profiles learn; metrics
    /// reported from the remainder).
    pub warmup_frac: f64,
    /// Scale factor on the per-instance KV pool (< 1.0 models co-tenant
    /// memory pressure; 1.0 = full A40 budget).
    pub kv_scale: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_instances: 4, // the paper's 4× A40 testbed
            model: ModelKind::Llama3_8B,
            block_size: 16,
            max_batch: 256, // vLLM's default max_num_seqs
            refresh_interval: 5.0,
            warmup_frac: 0.2,
            // The paper's shared public-cloud instances run under real KV
            // pressure (18.4% of requests preempted at 8 req/s under RR,
            // §2.2.3). A full 30 GB pool never fills at these request
            // sizes, so the default models the co-tenant-occupied pool
            // that makes memory a binding resource.
            kv_scale: 0.12,
        }
    }
}

/// Final result of a simulation run.
#[derive(Debug)]
pub struct SimResult {
    pub summary: RunSummary,
    pub metrics: MetricsCollector,
    pub sim_duration: Time,
    pub events_processed: u64,
    pub dropped_requests: u64,
    pub scheduler_name: &'static str,
    pub dispatcher_name: &'static str,
}

enum Ev {
    Arrival(usize),
    Step(usize),
    StepDone(usize, StepOutcome),
    Refresh,
}

struct WfState {
    plan: WorkflowPlan,
    next_stage: usize,
    app_start: Time,
    queue_time: f64,
    /// Isolated per-stage latency estimates (suffix sums give the ground
    /// truth remaining latency for Oracle/analysis).
    stage_latency: Vec<f64>,
}

struct Pending {
    msg_id: MsgId,
    agent: AgentId,
    stage_arrival: Time,
    dispatched_at: Time,
    output_tokens: u32,
    true_remaining: f64,
    upstream: Option<AgentId>,
}

/// The composed system under simulation.
pub struct SimServer {
    cfg: SimConfig,
    cost: CostModel,
    pub queue: RequestQueue,
    pub policy: Box<dyn SchedulePolicy>,
    pub dispatcher: Box<dyn DispatchPolicy>,
    engines: Vec<EngineCore<SimBackend>>,
    engine_busy: Vec<bool>,
    pub orch: Orchestrator,
    pub metrics: MetricsCollector,
    workflows: HashMap<MsgId, WfState>,
    pending: HashMap<RequestId, Pending>,
    next_req_id: RequestId,
    next_msg_id: MsgId,
    dropped: u64,
}

impl SimServer {
    pub fn new(
        cfg: SimConfig,
        policy: Box<dyn SchedulePolicy>,
        dispatcher: Box<dyn DispatchPolicy>,
    ) -> SimServer {
        let cost = CostModel::new(cfg.model);
        let mut ecfg = EngineConfig::for_model(&cost, cfg.block_size);
        ecfg.max_batch = cfg.max_batch;
        ecfg.total_blocks =
            ((ecfg.total_blocks as f64) * cfg.kv_scale).max(1.0) as u32;
        let engines = (0..cfg.n_instances)
            .map(|i| EngineCore::new(i, ecfg, SimBackend::new(cost)))
            .collect();
        SimServer {
            cfg,
            cost,
            queue: RequestQueue::new(),
            policy,
            dispatcher,
            engines,
            engine_busy: vec![false; cfg.n_instances],
            orch: Orchestrator::new(),
            metrics: MetricsCollector::new(),
            workflows: HashMap::new(),
            pending: HashMap::new(),
            next_req_id: 1,
            next_msg_id: 1,
            dropped: 0,
        }
    }

    /// Isolated (uncontended) execution latency of one stage — prefill plus
    /// single-stream decode under the cost model. Used for the ground-truth
    /// remaining-latency annotations.
    fn stage_isolated_latency(cost: &CostModel, prompt: u32, output: u32) -> f64 {
        let prefill = cost.step_time(prompt, 0, 0);
        let avg_ctx = prompt as u64 + output as u64 / 2;
        let per_tok = cost.step_time(0, 1, avg_ctx);
        prefill + per_tok * output.saturating_sub(1) as f64
    }

    fn make_request(&mut self, msg_id: MsgId, now: Time) -> Request {
        let wf = self.workflows.get_mut(&msg_id).expect("workflow exists");
        let i = wf.next_stage;
        let stage = &wf.plan.stages[i];
        let agent = self.orch.registry.intern(stage.agent);
        let upstream = if i > 0 {
            Some(self.orch.registry.intern(wf.plan.stages[i - 1].agent))
        } else {
            None
        };
        let true_remaining: f64 = wf.stage_latency[i..].iter().sum();
        let id = self.next_req_id;
        self.next_req_id += 1;
        self.pending.insert(
            id,
            Pending {
                msg_id,
                agent,
                stage_arrival: now,
                dispatched_at: now,
                output_tokens: stage.output_tokens,
                true_remaining,
                upstream,
            },
        );
        Request {
            id,
            msg_id,
            agent,
            upstream,
            prompt_tokens: stage.prompt_tokens,
            true_output_tokens: stage.output_tokens,
            true_remaining_latency: true_remaining,
            remaining_stages: wf.plan.remaining_stages(i),
            app_start: wf.app_start,
            stage_arrival: now,
        }
    }

    fn pump(&mut self, now: Time, events: &mut EventQueue<Ev>) {
        if self.queue.is_empty() {
            return;
        }
        // Snapshot instance statuses once per pump; only the engine that
        // received the previous dispatch changes, so refresh just that one.
        let mut statuses: Vec<_> = self.engines.iter().map(|e| e.status()).collect();
        loop {
            if self.queue.is_empty() {
                return;
            }
            // Schedule the highest-priority request; the dispatcher picks
            // its instance. Baseline dispatchers (Round-Robin) hand it over
            // immediately — the engine-side queue absorbs the backlog, as
            // vLLM does — while Kairos' time-slot packer may defer
            // ("the request remains in the scheduling queue", §6).
            let Some(best) = self.queue.peek_best() else {
                return;
            };
            // A prompt that can never fit any instance is rejected outright.
            let need_tokens = best.prompt_tokens as u64 + 1;
            if statuses.iter().all(|s| need_tokens > s.capacity_tokens) {
                let req = self.queue.pop_best().unwrap();
                self.pending.remove(&req.id);
                self.workflows.remove(&req.msg_id);
                self.dropped += 1;
                continue;
            }
            let Some(j) = self.dispatcher.choose(best, &statuses, now) else {
                return;
            };
            let req = self.queue.pop_best().expect("peeked request still queued");
            self.dispatcher.on_dispatch(&req, j, now);
            self.engines[j].submit(req, now);
            self.wake_engine(j, now, events);
            statuses[j] = self.engines[j].status();
        }
    }

    fn wake_engine(&mut self, j: usize, now: Time, events: &mut EventQueue<Ev>) {
        if !self.engine_busy[j] && self.engines[j].has_work() {
            self.engine_busy[j] = true;
            events.schedule(now, Ev::Step(j));
        }
    }

    fn handle_completion(
        &mut self,
        seq: crate::engine::request::SeqState,
        instance: usize,
        now: Time,
        events: &mut EventQueue<Ev>,
    ) {
        let req = seq.req.clone();
        let Some(mut p) = self.pending.remove(&req.id) else { return };
        // Queueing ends at FIRST admission into the running batch (the LLM
        // execution start); everything before is queue time, wherever the
        // request physically waited (LB queue or engine queue).
        p.dispatched_at = seq.first_admitted_at.unwrap_or(now);
        self.dispatcher.on_complete(req.id, instance, now);
        if let Some(wf) = self.workflows.get_mut(&req.msg_id) {
            wf.queue_time += p.dispatched_at - p.stage_arrival;
        }
        self.metrics.record_request(RequestRecord {
            msg_id: p.msg_id,
            agent: p.agent,
            stage_arrival: p.stage_arrival,
            dispatched_at: p.dispatched_at,
            finished_at: now,
            output_tokens: p.output_tokens,
            preempt_count: seq.preempt_count,
            true_remaining: p.true_remaining,
        });
        self.orch.record_execution(ExecRecord {
            msg_id: p.msg_id,
            agent: p.agent,
            upstream: p.upstream,
            start: p.dispatched_at,
            end: now,
        });
        // Advance the workflow.
        let done = {
            let wf = self.workflows.get_mut(&p.msg_id).expect("workflow");
            wf.next_stage += 1;
            wf.next_stage >= wf.plan.stages.len()
        };
        if done {
            let wf = self.workflows.get(&p.msg_id).unwrap();
            self.metrics.record_workflow(WorkflowRecord {
                msg_id: p.msg_id,
                app: wf.plan.app,
                app_start: wf.app_start,
                finished_at: now,
                output_tokens: wf.plan.total_output_tokens(),
                queue_time: wf.queue_time,
            });
            self.orch.record_workflow_done(p.msg_id, now);
            self.workflows.remove(&p.msg_id);
        } else {
            let req = self.make_request(p.msg_id, now);
            self.queue.push(req, self.policy.as_ref());
        }
        let _ = events;
    }

    /// Run the full trace to completion; returns the run summary filtered
    /// past the warmup fraction.
    pub fn run(mut self, arrivals: Vec<ArrivalEvent>) -> SimResult {
        let mut events: EventQueue<Ev> = EventQueue::new();
        let warmup_time = arrivals
            .get(((arrivals.len() as f64 * self.cfg.warmup_frac) as usize)
                .min(arrivals.len().saturating_sub(1)))
            .map(|a| a.at)
            .unwrap_or(0.0);
        for (i, a) in arrivals.iter().enumerate() {
            events.schedule(a.at, Ev::Arrival(i));
        }
        events.schedule(self.cfg.refresh_interval, Ev::Refresh);

        let event_cap: u64 = 200_000_000;
        while let Some((now, ev)) = events.pop() {
            match ev {
                Ev::Arrival(i) => {
                    let plan = arrivals[i].plan.clone();
                    let stage_latency: Vec<f64> = plan
                        .stages
                        .iter()
                        .map(|s| {
                            Self::stage_isolated_latency(
                                &self.cost,
                                s.prompt_tokens,
                                s.output_tokens,
                            )
                        })
                        .collect();
                    let msg_id = self.next_msg_id;
                    self.next_msg_id += 1;
                    self.workflows.insert(
                        msg_id,
                        WfState {
                            plan,
                            next_stage: 0,
                            app_start: now,
                            queue_time: 0.0,
                            stage_latency,
                        },
                    );
                    let req = self.make_request(msg_id, now);
                    self.queue.push(req, self.policy.as_ref());
                    self.pump(now, &mut events);
                }
                Ev::Step(j) => {
                    // The scheduling policy governs the engine-side queue
                    // (vLLM pluggable scheduling): re-order before admission
                    // whenever membership changed or priorities refreshed.
                    if self.engines[j].waiting_dirty {
                        let policy = &self.policy;
                        self.engines[j].sort_waiting_by(|r| policy.key(r));
                    }
                    let out = self.engines[j].step(now);
                    if out.duration > 0.0 {
                        events.schedule(now + out.duration, Ev::StepDone(j, out));
                    } else {
                        self.engine_busy[j] = false;
                        // Idle with queued work that can never fit: the
                        // front request alone exceeds the pool. Drop it.
                        if self.engines[j].batch_len() == 0
                            && self.engines[j].waiting_len() > 0
                        {
                            for req in self.engines[j].drain() {
                                self.pending.remove(&req.id);
                                self.workflows.remove(&req.msg_id);
                                self.dropped += 1;
                            }
                        }
                    }
                }
                Ev::StepDone(j, out) => {
                    if out.preempted > 0 {
                        self.metrics.preemptions += out.preempted as u64;
                        self.dispatcher.on_preemption(j, now);
                    }
                    for seq in out.completed {
                        self.handle_completion(seq, j, now, &mut events);
                    }
                    self.engine_busy[j] = false;
                    self.wake_engine(j, now, &mut events);
                    self.pump(now, &mut events);
                }
                Ev::Refresh => {
                    self.policy.refresh(&self.orch);
                    self.dispatcher.refresh(&self.orch);
                    // Re-key the central queue under the moved priorities.
                    self.queue.resort(self.policy.as_ref());
                    // Priorities may have moved: every engine queue is stale.
                    for e in self.engines.iter_mut() {
                        e.waiting_dirty = true;
                    }
                    if !self.workflows.is_empty() || !events.is_empty() {
                        events.schedule(now + self.cfg.refresh_interval, Ev::Refresh);
                    }
                }
            }
            if events.processed() > event_cap {
                panic!("simulation exceeded event cap (livelock?)");
            }
            // Refresh events keep themselves alive only while work remains;
            // drain them if they are the only thing left.
            if self.workflows.is_empty()
                && self.queue.is_empty()
                && events.len() >= 1
                && self.engines.iter().all(|e| !e.has_work())
            {
                let arrivals_left = {
                    // any future arrivals still scheduled?
                    // (cheap check: events may hold Refresh only)
                    events.len()
                };
                let _ = arrivals_left;
            }
        }

        // Aggregate engine counters.
        for e in &self.engines {
            self.metrics.recomputed_tokens += e.recomputed_tokens;
            self.metrics.total_tokens += 0; // already counted per request
        }
        let sim_duration = events.now();
        let summary = self
            .metrics
            .summary_from(warmup_time)
            .or_else(|| self.metrics.summary())
            .expect("no workflows completed");
        SimResult {
            summary,
            sim_duration,
            events_processed: events.processed(),
            dropped_requests: self.dropped,
            scheduler_name: self.policy.name(),
            dispatcher_name: self.dispatcher.name(),
            metrics: self.metrics,
        }
    }
}

/// Build a scheduler by name: "parrot" (FCFS), "ayo" (topo), "kairos",
/// "oracle".
pub fn make_policy(name: &str) -> Box<dyn SchedulePolicy> {
    use crate::lb::policies::*;
    match name {
        "parrot" | "fcfs" => Box::new(Fcfs),
        "ayo" | "topo" => Box::new(Topo),
        "kairos" => Box::new(KairosPolicy::new()),
        "oracle" => Box::new(Oracle),
        other => panic!("unknown scheduler {other:?}"),
    }
}

/// Build a dispatcher by name: "rr", "kairos", "oracle", "least".
pub fn make_dispatcher(name: &str, cfg: &SimConfig) -> Box<dyn DispatchPolicy> {
    use crate::dispatch::*;
    let cost = CostModel::new(cfg.model);
    match name {
        "rr" | "round-robin" => Box::new(RoundRobin::new()),
        "kairos" | "timeslot" => {
            let mut ts = crate::dispatch::timeslot::TimeSlotConfig::for_cost_model(&cost);
            ts.capacity_bytes *= cfg.kv_scale;
            Box::new(TimeSlotDispatcher::new(cfg.n_instances, ts))
        }
        "oracle" => Box::new(OracleFit::new(cfg.n_instances)),
        "least" | "least-loaded" => Box::new(LeastLoaded::new()),
        other => panic!("unknown dispatcher {other:?}"),
    }
}

/// Convenience: run `(scheduler, dispatcher)` over a trace with `cfg`.
pub fn run_system(
    cfg: SimConfig,
    scheduler: &str,
    dispatcher: &str,
    arrivals: Vec<ArrivalEvent>,
) -> SimResult {
    let policy = make_policy(scheduler);
    let disp = make_dispatcher(dispatcher, &cfg);
    SimServer::new(cfg, policy, disp).run(arrivals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::apps::App;
    use crate::stats::rng::Rng;
    use crate::workload::{TraceGen, WorkloadMix};

    fn trace(n: usize, rate: f64, seed: u64) -> Vec<ArrivalEvent> {
        TraceGen::default().generate(&WorkloadMix::colocated(), rate, n, &mut Rng::new(seed))
    }

    #[test]
    fn all_workflows_complete_under_light_load() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let arrivals = trace(60, 1.0, 1);
        let res = run_system(cfg, "parrot", "rr", arrivals);
        assert_eq!(res.dropped_requests, 0);
        assert!(res.summary.n_workflows > 0);
        assert!(res.summary.avg_token_latency > 0.0);
        // Light load: queueing should be a small share.
        assert!(res.summary.mean_queue_ratio < 0.5, "{}", res.summary.mean_queue_ratio);
    }

    #[test]
    fn heavy_load_queues_more_than_light() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let light = run_system(cfg, "parrot", "rr", trace(60, 0.5, 2));
        let heavy = run_system(cfg, "parrot", "rr", trace(300, 12.0, 2));
        assert!(
            heavy.summary.mean_queue_ratio > light.summary.mean_queue_ratio,
            "heavy {} vs light {}",
            heavy.summary.mean_queue_ratio,
            light.summary.mean_queue_ratio
        );
    }

    #[test]
    fn kairos_beats_fcfs_under_excessive_load() {
        // The headline claim (directionally): under heavy queuing, Kairos'
        // scheduling+dispatching reduces avg token latency vs Parrot.
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let parrot = run_system(cfg, "parrot", "rr", trace(400, 10.0, 3));
        let kairos = run_system(cfg, "kairos", "kairos", trace(400, 10.0, 3));
        assert!(
            kairos.summary.avg_token_latency < parrot.summary.avg_token_latency,
            "kairos {} !< parrot {}",
            kairos.summary.avg_token_latency,
            parrot.summary.avg_token_latency
        );
    }

    #[test]
    fn orchestrator_learns_workflow_structure_online() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let arrivals = TraceGen::default().generate(
            &WorkloadMix::single(App::Qa, "G+M"),
            2.0,
            80,
            &mut Rng::new(4),
        );
        let policy = make_policy("kairos");
        let disp = make_dispatcher("rr", &cfg);
        let server = SimServer::new(cfg, policy, disp);
        // run consumes server; inspect through the result's metrics +
        // rebuild a server to inspect the orchestrator... instead assert on
        // request records: both experts appear downstream of the router.
        let res = server.run(arrivals);
        assert!(res.summary.n_workflows > 10);
        // Each QA workflow contributed exactly 2 stage records.
        assert_eq!(res.metrics.requests.len() % 2, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let a = run_system(cfg, "kairos", "kairos", trace(100, 6.0, 7));
        let b = run_system(cfg, "kairos", "kairos", trace(100, 6.0, 7));
        assert_eq!(a.summary.n_workflows, b.summary.n_workflows);
        assert!((a.summary.avg_token_latency - b.summary.avg_token_latency).abs() < 1e-12);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn oracle_scheduler_at_least_as_good_as_fcfs() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let fcfs = run_system(cfg, "parrot", "rr", trace(300, 10.0, 8));
        let oracle = run_system(cfg, "oracle", "rr", trace(300, 10.0, 8));
        assert!(
            oracle.summary.avg_token_latency <= fcfs.summary.avg_token_latency * 1.05,
            "oracle {} vs fcfs {}",
            oracle.summary.avg_token_latency,
            fcfs.summary.avg_token_latency
        );
    }
}
