//! Virtual-time driver over the shared serving runtime.
//!
//! A discrete-event loop drives the clock-agnostic
//! [`Coordinator`](super::coordinator::Coordinator): user tasks arrive
//! (bursty trace), each workflow emits its stages as LLM requests into the
//! central queue, the active [`SchedulePolicy`] picks the next request, the
//! active [`DispatchPolicy`] places it on an engine instance, engines run
//! continuous-batching iterations under the calibrated cost model, and
//! completions feed the orchestrator, whose profiles in turn drive Kairos'
//! scheduler/dispatcher refreshes. All of that coordination logic lives in
//! the coordinator; this module only owns the event queue and the virtual
//! clock.

use crate::dispatch::DispatchPolicy;
use crate::engine::core::{SimBackend, StepOutcome};
use crate::engine::cost_model::ModelKind;
use crate::lb::policies::SchedulePolicy;
use crate::metrics::{MetricsCollector, RunSummary};
use crate::orchestrator::affinity::AffinitySpec;
use crate::orchestrator::router::{RouteDecision, RoutePolicy};
use crate::server::autoscale::{AutoscaleConfig, Autoscaler};
use crate::server::coordinator::{
    Coordinator, FleetSpec, GroupDispatch, InstanceSpec, LogConfig, ScaleEvent,
};
use crate::server::pressure::PressureTrace;
use crate::simcore::EventQueue;
use crate::workload::trace::TraceRecord;
use crate::workload::ArrivalEvent;
use crate::Time;

/// Simulation configuration for a homogeneous fleet (the paper's testbed).
/// For mixed fleets use [`FleetConfig`] directly.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub n_instances: usize,
    pub model: ModelKind,
    pub block_size: u32,
    /// vLLM max_num_seqs per instance.
    pub max_batch: usize,
    /// Priority/profile refresh period (paper §7.7: fixed intervals,
    /// asynchronous).
    pub refresh_interval: f64,
    /// Fraction of the trace treated as warmup (profiles learn; metrics
    /// reported from the remainder).
    pub warmup_frac: f64,
    /// Scale factor on the per-instance KV pool (< 1.0 models co-tenant
    /// memory pressure; 1.0 = full A40 budget).
    pub kv_scale: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_instances: 4, // the paper's 4× A40 testbed
            model: ModelKind::Llama3_8B,
            block_size: 16,
            max_batch: 256, // vLLM's default max_num_seqs
            refresh_interval: 5.0,
            warmup_frac: 0.2,
            // The paper's shared public-cloud instances run under real KV
            // pressure (18.4% of requests preempted at 8 req/s under RR,
            // §2.2.3). A full 30 GB pool never fills at these request
            // sizes, so the default models the co-tenant-occupied pool
            // that makes memory a binding resource.
            kv_scale: 0.12,
        }
    }
}

impl SimConfig {
    /// The homogeneous fleet this config describes.
    pub fn fleet(&self) -> FleetSpec {
        let spec = InstanceSpec {
            model: self.model,
            block_size: self.block_size,
            max_batch: self.max_batch,
            kv_scale: self.kv_scale,
            cache_blocks: 0,
        };
        FleetSpec::homogeneous(self.n_instances, spec)
    }
}

/// Full simulation configuration: an arbitrary (possibly heterogeneous)
/// fleet plus the run parameters, optionally elastic (autoscaling) and
/// under a time-varying co-tenant pressure trace.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub fleet: FleetSpec,
    pub refresh_interval: f64,
    pub warmup_frac: f64,
    /// When set, the coordinator grows/drains the fleet on refresh ticks.
    pub autoscale: Option<AutoscaleConfig>,
    /// When set, per-instance KV budgets move over time.
    pub pressure: Option<PressureTrace>,
    /// When set, agents are pinned to model-affine serving groups and the
    /// central queue shards accordingly.
    pub affinity: Option<AffinitySpec>,
    /// When set, the routing layer's policy (default: `Pinned`, the
    /// static affinity stamp). `Learned` also switches the time-slot
    /// dispatcher to the profile-driven KV-demand prediction.
    pub route: Option<RoutePolicy>,
    /// When set, the per-family latency profiles decay with this
    /// half-life (seconds), so learned routing tracks non-stationary
    /// workloads (`[policy] profile_half_life`).
    pub profile_half_life: Option<f64>,
    /// Retention caps for the coordinator's decision logs (default: keep
    /// everything). Million-request bench runs bound these; capping
    /// changes retention only, never decisions.
    pub logs: LogConfig,
    /// When set, the metrics collector keeps no per-record vectors — only
    /// counters and streaming sketches — so memory stays flat over
    /// million-request runs (the summary comes from the sketches).
    pub lean_metrics: bool,
    /// Run the coordinator's pre-index hot path (linear candidate scans,
    /// per-call pressure rebuilds, unbatched refresh) — the bench
    /// harness's in-binary baseline arm.
    pub legacy_hot_path: bool,
    /// Run the dispatcher's naive candidate-scoring arm (linear peak
    /// scans, per-candidate ramp recompute) instead of the max-tree arm —
    /// the `pack` bench's baseline. Orthogonal to `legacy_hot_path`;
    /// decisions are identical either way.
    pub legacy_scoring: bool,
    /// Prefix-cache tuning (`[cache]` / `--cache`): engine-side cache
    /// budget plus the CHWBL bounded-load factor the `cache-affine`
    /// dispatcher arm uses. Disabled by default.
    pub cache: CacheTuning,
    /// Worker threads for the coordinator's score-in-parallel pump
    /// (`--threads`). `1` (the default) keeps the sequential reference
    /// arm; decisions are bit-identical at every value.
    pub threads: usize,
}

/// Prefix-cache knobs shared by the engine-side cache, the time-slot
/// packer's session-aware prefill estimate, and the `cache-affine`
/// session-sticky dispatch layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheTuning {
    /// Turn the per-instance prefix cache on (and let the `kairos`
    /// packer shorten its expected-prefill estimate for warm sessions).
    pub enabled: bool,
    /// Per-instance prefix-cache budget in KV blocks.
    pub budget_blocks: u32,
    /// CHWBL bounded-load factor (≥ 1.0) for the `cache-affine`
    /// dispatcher: a sticky target may hold at most
    /// `ceil(load_factor × mean in-flight load)` dispatches.
    pub load_factor: f64,
}

impl Default for CacheTuning {
    fn default() -> Self {
        CacheTuning { enabled: false, budget_blocks: 512, load_factor: 1.25 }
    }
}

impl CacheTuning {
    /// The default tuning with the cache switched on.
    pub fn on() -> CacheTuning {
        CacheTuning { enabled: true, ..CacheTuning::default() }
    }
}

impl From<SimConfig> for FleetConfig {
    fn from(cfg: SimConfig) -> FleetConfig {
        FleetConfig {
            fleet: cfg.fleet(),
            refresh_interval: cfg.refresh_interval,
            warmup_frac: cfg.warmup_frac,
            autoscale: None,
            pressure: None,
            affinity: None,
            route: None,
            profile_half_life: None,
            logs: LogConfig::full(),
            lean_metrics: false,
            legacy_hot_path: false,
            legacy_scoring: false,
            cache: CacheTuning::default(),
            threads: 1,
        }
    }
}

impl From<FleetSpec> for FleetConfig {
    fn from(fleet: FleetSpec) -> FleetConfig {
        let d = SimConfig::default();
        FleetConfig {
            fleet,
            refresh_interval: d.refresh_interval,
            warmup_frac: d.warmup_frac,
            autoscale: None,
            pressure: None,
            affinity: None,
            route: None,
            profile_half_life: None,
            logs: LogConfig::full(),
            lean_metrics: false,
            legacy_hot_path: false,
            legacy_scoring: false,
            cache: CacheTuning::default(),
            threads: 1,
        }
    }
}

/// Final result of a simulation run.
#[derive(Debug)]
pub struct SimResult {
    pub summary: RunSummary,
    pub metrics: MetricsCollector,
    pub sim_duration: Time,
    pub events_processed: u64,
    pub dropped_requests: u64,
    pub scheduler_name: &'static str,
    pub dispatcher_name: &'static str,
    /// Every dispatch decision `(request, instance)` in order.
    pub dispatch_log: Vec<(u64, usize)>,
    /// The dispatch log with serving-group context (class + instance
    /// model per decision); per-group views and the no-cross-model check
    /// read this.
    pub group_log: Vec<GroupDispatch>,
    /// Every routing decision, in submission order (the routing layer's
    /// leg of the driver-equivalence seam).
    pub route_log: Vec<RouteDecision>,
    /// Every fleet change (grow / drain start / drain done), in order.
    pub scale_log: Vec<ScaleEvent>,
    /// Every submitted plan with its ground-truth submission time — the
    /// run's recorded workload ([`crate::workload::Trace::from_records`]
    /// turns it into a replayable JSONL artifact).
    pub trace_log: Vec<TraceRecord>,
    /// Instances still active when the run ended.
    pub final_active_instances: usize,
    /// Resident bytes the decision logs pinned at end of run (the bench
    /// harness's `peak_log_bytes`; bounded by [`LogConfig`] caps).
    pub log_state_bytes: usize,
    /// Dispatch decisions ever made, including ones a bounded log evicted
    /// (`dispatch_log.len()` when logs are unbounded).
    pub dispatched_total: u64,
    /// Invariant audits run during the replay (0 unless
    /// [`SimServer::enable_audit`] was called).
    pub audit_checks: usize,
    /// Violations the audits reported, each prefixed with the sim time of
    /// the failing check. Empty on a healthy run.
    pub audit_violations: Vec<String>,
}

impl SimResult {
    /// Mean per-stage queuing delay in seconds (arrival at the load
    /// balancer to first admission into a running batch); 0 when no
    /// request finished.
    pub fn mean_queue_delay(&self) -> f64 {
        let reqs = &self.metrics.requests;
        if reqs.is_empty() {
            return 0.0;
        }
        reqs.iter().map(|r| r.queue_time()).sum::<f64>() / reqs.len() as f64
    }

    /// Mean per-request end-to-end latency in seconds (stage arrival to
    /// completion); 0 when no request finished. The route-sweep's
    /// pinned-vs-learned comparison metric.
    pub fn mean_request_e2e(&self) -> f64 {
        let reqs = &self.metrics.requests;
        if reqs.is_empty() {
            return 0.0;
        }
        reqs.iter().map(|r| r.finished_at - r.stage_arrival).sum::<f64>()
            / reqs.len() as f64
    }

    /// Dispatch decisions that landed on an instance whose model family
    /// the request was not pinned to. Must be zero: the sharded queue and
    /// every dispatcher filter candidates by model class, and the
    /// coordinator asserts it per dispatch.
    pub fn cross_model_dispatches(&self) -> usize {
        self.group_log.iter().filter(|g| !g.class.matches(g.model)).count()
    }

    /// Prefix-cache traffic counters folded from every engine at end of
    /// run (all-zero when the cache is disabled).
    pub fn cache_stats(&self) -> crate::metrics::CacheStats {
        self.metrics.stream.cache
    }

    /// KV-block allocation failures across the fleet, folded from every
    /// engine at end of run.
    pub fn alloc_failures(&self) -> u64 {
        self.metrics.stream.alloc_failures
    }

    /// `(grows, completed retirements)` of the run's scale log.
    pub fn scale_counts(&self) -> (usize, usize) {
        use crate::server::coordinator::ScaleEventKind;
        let grows = self
            .scale_log
            .iter()
            .filter(|e| e.kind == ScaleEventKind::Grow)
            .count();
        let retires = self
            .scale_log
            .iter()
            .filter(|e| e.kind == ScaleEventKind::RetireDone)
            .count();
        (grows, retires)
    }
}

enum Ev {
    Arrival(usize),
    Step(usize),
    StepDone(usize, StepOutcome),
    Refresh,
}

/// The discrete-event driver: an event queue and per-engine busy flags over
/// one shared [`Coordinator`].
pub struct SimServer {
    cfg: FleetConfig,
    coord: Coordinator<SimBackend>,
    engine_busy: Vec<bool>,
    audit: bool,
    audit_checks: usize,
    audit_violations: Vec<String>,
}

impl SimServer {
    pub fn new(
        cfg: SimConfig,
        policy: Box<dyn SchedulePolicy>,
        dispatcher: Box<dyn DispatchPolicy>,
    ) -> SimServer {
        SimServer::with_fleet(cfg.into(), policy, dispatcher)
    }

    /// Build a driver over an arbitrary (possibly heterogeneous) fleet,
    /// elastic when the config carries an autoscaler, under co-tenant
    /// pressure when it carries a trace.
    pub fn with_fleet(
        cfg: FleetConfig,
        policy: Box<dyn SchedulePolicy>,
        dispatcher: Box<dyn DispatchPolicy>,
    ) -> SimServer {
        let mut fleet = cfg.fleet.clone();
        if cfg.cache.enabled {
            // The cache budget is fleet-wide tuning; specs that carry
            // their own explicit budget keep it.
            for s in &mut fleet.instances {
                if s.cache_blocks == 0 {
                    s.cache_blocks = cfg.cache.budget_blocks;
                }
            }
        }
        let mut coord = Coordinator::sim(fleet, policy, dispatcher);
        if let Some(a) = cfg.autoscale.clone() {
            coord.set_autoscaler(Autoscaler::new(a));
        }
        if let Some(p) = cfg.pressure.clone() {
            coord.set_pressure(p);
        }
        if let Some(aff) = &cfg.affinity {
            coord.set_affinity(aff);
        }
        if let Some(route) = cfg.route {
            coord.set_route_policy(route);
        }
        coord.set_profile_half_life(cfg.profile_half_life);
        coord.set_log_config(cfg.logs);
        coord.metrics.lean = cfg.lean_metrics;
        coord.set_legacy_hot_path(cfg.legacy_hot_path);
        coord.set_legacy_scoring(cfg.legacy_scoring);
        coord.set_pump_threads(cfg.threads);
        let n = coord.n_instances();
        SimServer {
            cfg,
            coord,
            engine_busy: vec![false; n],
            audit: false,
            audit_checks: 0,
            audit_violations: Vec::new(),
        }
    }

    /// The underlying runtime (inspection in tests/analyses).
    pub fn coordinator(&self) -> &Coordinator<SimBackend> {
        &self.coord
    }

    /// Run [`Coordinator::audit_invariants`] on every refresh tick and at
    /// end of run, collecting violations into the result instead of
    /// panicking — works in release builds too (`kairos check`).
    pub fn enable_audit(&mut self) {
        self.audit = true;
    }

    fn run_audit(&mut self, now: Time) {
        if !self.audit {
            return;
        }
        self.audit_checks += 1;
        for v in self.coord.audit_invariants() {
            self.audit_violations.push(format!("t={now:.3}: {v}"));
        }
    }

    fn wake_engine(&mut self, j: usize, now: Time, events: &mut EventQueue<Ev>) {
        if !self.engine_busy[j] && self.coord.engines[j].has_work() {
            self.engine_busy[j] = true;
            events.schedule(now, Ev::Step(j));
        }
    }

    fn pump_and_wake(&mut self, now: Time, events: &mut EventQueue<Ev>) {
        let woken = self.coord.pump(now);
        // A provisioned instance whose boot delay elapsed registers inside
        // pump, so the fleet can grow on ANY pump — track it before waking.
        let n = self.coord.n_instances();
        if self.engine_busy.len() < n {
            self.engine_busy.resize(n, false);
        }
        for j in woken {
            self.wake_engine(j, now, events);
        }
    }

    /// Run the full trace to completion; returns the run summary filtered
    /// past the warmup fraction.
    pub fn run(mut self, arrivals: Vec<ArrivalEvent>) -> SimResult {
        let mut events: EventQueue<Ev> = EventQueue::new();
        let warmup_time = arrivals
            .get(((arrivals.len() as f64 * self.cfg.warmup_frac) as usize)
                .min(arrivals.len().saturating_sub(1)))
            .map(|a| a.at)
            .unwrap_or(0.0);
        for (i, a) in arrivals.iter().enumerate() {
            events.schedule(a.at, Ev::Arrival(i));
        }
        events.schedule(self.cfg.refresh_interval, Ev::Refresh);

        let event_cap: u64 = 200_000_000;
        while let Some((now, ev)) = events.pop() {
            match ev {
                Ev::Arrival(i) => {
                    self.coord.submit_plan_with_session(
                        arrivals[i].plan.clone(),
                        arrivals[i].session,
                        now,
                    );
                    self.pump_and_wake(now, &mut events);
                }
                Ev::Step(j) => {
                    let out = self.coord.step_engine(j, now);
                    if out.duration > 0.0 {
                        events.schedule(now + out.duration, Ev::StepDone(j, out));
                    } else {
                        self.engine_busy[j] = false;
                        // Idle with queued work that can never fit: the
                        // front request alone exceeds the pool. Drop it.
                        self.coord.drain_stuck(j);
                    }
                }
                Ev::StepDone(j, out) => {
                    self.coord.absorb(j, out, now);
                    self.engine_busy[j] = false;
                    self.wake_engine(j, now, &mut events);
                    self.pump_and_wake(now, &mut events);
                }
                Ev::Refresh => {
                    self.coord.refresh(now);
                    self.run_audit(now);
                    // Re-keyed priorities may unblock deferred requests:
                    // give them a dispatch chance without waiting for the
                    // next completion. (pump_and_wake also tracks any
                    // engines the autoscaler grew on this tick.)
                    self.pump_and_wake(now, &mut events);
                    if self.coord.open_workflows() > 0 || !events.is_empty() {
                        events.schedule(now + self.cfg.refresh_interval, Ev::Refresh);
                    }
                }
            }
            if events.processed() > event_cap {
                panic!("simulation exceeded event cap (livelock?)");
            }
        }

        let sim_duration = events.now();
        // Close out any instance still draining when the trace ended, then
        // sweep the (idempotent) per-engine counters.
        self.coord.finalize_drained(sim_duration);
        self.coord.fold_engine_counters();
        self.run_audit(sim_duration);
        // Lean runs retain no per-workflow records; their summary comes
        // from the streaming sketches (whole run, no warmup filtering). A
        // run where nothing completed still yields a (zeroed) summary
        // rather than a panic on the serving layer (lint D6).
        let summary = self
            .coord
            .metrics
            .summary_from(warmup_time)
            .or_else(|| self.coord.metrics.summary())
            .or_else(|| self.coord.metrics.streaming_summary())
            .unwrap_or_default();
        let log_state_bytes = self.coord.log_state_bytes();
        let dispatched_total = self.coord.dispatch_log.total();
        SimResult {
            summary,
            sim_duration,
            events_processed: events.processed(),
            dropped_requests: self.coord.dropped,
            scheduler_name: self.coord.policy.name(),
            dispatcher_name: self.coord.dispatcher.name(),
            dispatch_log: self.coord.dispatch_log.take_vec(),
            group_log: self.coord.group_log.take_vec(),
            route_log: self.coord.route_log.take_vec(),
            scale_log: self.coord.scale_log.take_vec(),
            trace_log: self.coord.trace_log.take_vec(),
            final_active_instances: self.coord.active_instances(),
            log_state_bytes,
            dispatched_total,
            audit_checks: self.audit_checks,
            audit_violations: self.audit_violations,
            metrics: self.coord.metrics,
        }
    }
}

/// Build a scheduler by name: "parrot" (FCFS), "ayo" (topo), "kairos",
/// "oracle".
pub fn make_policy(name: &str) -> Box<dyn SchedulePolicy> {
    use crate::lb::policies::*;
    match name {
        "parrot" | "fcfs" => Box::new(Fcfs),
        "ayo" | "topo" => Box::new(Topo),
        "kairos" => Box::new(KairosPolicy::new()),
        "oracle" => Box::new(Oracle),
        other => panic!("unknown scheduler {other:?}"),
    }
}

/// Build a dispatcher by name for an arbitrary fleet: "rr", "kairos",
/// "oracle", "least". The time-slot dispatcher takes its ramp constants
/// from the fleet's reference cost model and its per-instance capacities
/// live from [`crate::engine::core::InstanceStatus`].
pub fn make_dispatcher_for_fleet(name: &str, fleet: &FleetSpec) -> Box<dyn DispatchPolicy> {
    make_dispatcher_routed(name, fleet, None)
}

/// [`make_dispatcher_for_fleet`] with the routing layer's policy: under
/// `Learned` routing the time-slot packer predicts each request's KV
/// demand from the profiler's learned per-agent demand distribution
/// instead of the slope-based guess (the baselines ignore the policy).
pub fn make_dispatcher_routed(
    name: &str,
    fleet: &FleetSpec,
    route: Option<&RoutePolicy>,
) -> Box<dyn DispatchPolicy> {
    make_dispatcher_tuned(name, fleet, route, None)
}

/// [`make_dispatcher_routed`] with the prefix-cache tuning: an enabled
/// [`CacheTuning`] makes the time-slot packer shorten its expected-prefill
/// estimate for warm sessions, and parameterizes the `cache-affine` arm's
/// CHWBL bounded-load factor.
pub fn make_dispatcher_tuned(
    name: &str,
    fleet: &FleetSpec,
    route: Option<&RoutePolicy>,
    cache: Option<&CacheTuning>,
) -> Box<dyn DispatchPolicy> {
    use crate::dispatch::*;
    match name {
        "rr" | "round-robin" => Box::new(RoundRobin::new()),
        "kairos" | "timeslot" => {
            let cost = fleet.reference_cost();
            let mut ts = crate::dispatch::timeslot::TimeSlotConfig::for_cost_model(&cost);
            // Fallback capacity when no live status is available: the
            // smallest instance's budget (per-instance budgets come from
            // the statuses on every decision).
            let min_scale = fleet
                .instances
                .iter()
                .map(|s| s.kv_scale)
                .fold(f64::INFINITY, f64::min);
            if min_scale.is_finite() {
                ts.capacity_bytes *= min_scale;
            }
            ts.learned_demand = matches!(route, Some(RoutePolicy::Learned { .. }));
            ts.cache_aware = cache.is_some_and(|c| c.enabled);
            // Each instance is priced with ITS OWN cost model (ramp slope
            // + KV density), not the fleet reference's.
            let models: Vec<ModelKind> =
                fleet.instances.iter().map(|s| s.model).collect();
            Box::new(TimeSlotDispatcher::for_models(&models, ts))
        }
        "cache-affine" | "affine" => {
            // Session-sticky CHWBL over the cache-aware packer: sticky
            // picks keep a session's stages on the instance holding its
            // prefix; overloaded targets fall back to the packer score.
            let tuning = cache.copied().unwrap_or_else(CacheTuning::on);
            let inner = make_dispatcher_tuned("kairos", fleet, route, Some(&tuning));
            let cfg = CacheAffineConfig {
                load_factor: tuning.load_factor.max(1.0),
                ..CacheAffineConfig::default()
            };
            Box::new(CacheAffine::new(cfg, fleet.len(), inner))
        }
        "oracle" => Box::new(OracleFit::new(fleet.len())),
        "least" | "least-loaded" => Box::new(LeastLoaded::new()),
        other => panic!("unknown dispatcher {other:?}"),
    }
}

/// Build a dispatcher by name for a homogeneous [`SimConfig`] fleet.
pub fn make_dispatcher(name: &str, cfg: &SimConfig) -> Box<dyn DispatchPolicy> {
    make_dispatcher_for_fleet(name, &cfg.fleet())
}

/// Convenience: run `(scheduler, dispatcher)` over a trace with `cfg`.
pub fn run_system(
    cfg: SimConfig,
    scheduler: &str,
    dispatcher: &str,
    arrivals: Vec<ArrivalEvent>,
) -> SimResult {
    run_fleet(cfg.into(), scheduler, dispatcher, arrivals)
}

/// Run `(scheduler, dispatcher)` over a trace on an arbitrary fleet.
pub fn run_fleet(
    cfg: FleetConfig,
    scheduler: &str,
    dispatcher: &str,
    arrivals: Vec<ArrivalEvent>,
) -> SimResult {
    let policy = make_policy(scheduler);
    let disp =
        make_dispatcher_tuned(dispatcher, &cfg.fleet, cfg.route.as_ref(), Some(&cfg.cache));
    SimServer::with_fleet(cfg, policy, disp).run(arrivals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::apps::App;
    use crate::stats::rng::Rng;
    use crate::workload::{TraceGen, WorkloadMix};

    fn trace(n: usize, rate: f64, seed: u64) -> Vec<ArrivalEvent> {
        TraceGen::default().generate(&WorkloadMix::colocated(), rate, n, &mut Rng::new(seed))
    }

    #[test]
    fn all_workflows_complete_under_light_load() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let arrivals = trace(60, 1.0, 1);
        let res = run_system(cfg, "parrot", "rr", arrivals);
        assert_eq!(res.dropped_requests, 0);
        assert!(res.summary.n_workflows > 0);
        assert!(res.summary.avg_token_latency > 0.0);
        // Light load: queueing should be a small share.
        assert!(res.summary.mean_queue_ratio < 0.5, "{}", res.summary.mean_queue_ratio);
    }

    #[test]
    fn heavy_load_queues_more_than_light() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let light = run_system(cfg, "parrot", "rr", trace(60, 0.5, 2));
        let heavy = run_system(cfg, "parrot", "rr", trace(300, 12.0, 2));
        assert!(
            heavy.summary.mean_queue_ratio > light.summary.mean_queue_ratio,
            "heavy {} vs light {}",
            heavy.summary.mean_queue_ratio,
            light.summary.mean_queue_ratio
        );
    }

    #[test]
    fn kairos_beats_fcfs_under_excessive_load() {
        // The headline claim (directionally): under heavy queuing, Kairos'
        // scheduling+dispatching reduces avg token latency vs Parrot.
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let parrot = run_system(cfg, "parrot", "rr", trace(400, 10.0, 3));
        let kairos = run_system(cfg, "kairos", "kairos", trace(400, 10.0, 3));
        assert!(
            kairos.summary.avg_token_latency < parrot.summary.avg_token_latency,
            "kairos {} !< parrot {}",
            kairos.summary.avg_token_latency,
            parrot.summary.avg_token_latency
        );
    }

    #[test]
    fn orchestrator_learns_workflow_structure_online() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let arrivals = TraceGen::default().generate(
            &WorkloadMix::single(App::Qa, "G+M"),
            2.0,
            80,
            &mut Rng::new(4),
        );
        let policy = make_policy("kairos");
        let disp = make_dispatcher("rr", &cfg);
        let server = SimServer::new(cfg, policy, disp);
        let res = server.run(arrivals);
        assert!(res.summary.n_workflows > 10);
        // Each QA workflow contributed exactly 2 stage records.
        assert_eq!(res.metrics.requests.len() % 2, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let a = run_system(cfg, "kairos", "kairos", trace(100, 6.0, 7));
        let b = run_system(cfg, "kairos", "kairos", trace(100, 6.0, 7));
        assert_eq!(a.summary.n_workflows, b.summary.n_workflows);
        assert!((a.summary.avg_token_latency - b.summary.avg_token_latency).abs() < 1e-12);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.dispatch_log, b.dispatch_log);
    }

    #[test]
    fn oracle_scheduler_at_least_as_good_as_fcfs() {
        let cfg = SimConfig { n_instances: 2, ..Default::default() };
        let fcfs = run_system(cfg, "parrot", "rr", trace(300, 10.0, 8));
        let oracle = run_system(cfg, "oracle", "rr", trace(300, 10.0, 8));
        assert!(
            oracle.summary.avg_token_latency <= fcfs.summary.avg_token_latency * 1.05,
            "oracle {} vs fcfs {}",
            oracle.summary.avg_token_latency,
            fcfs.summary.avg_token_latency
        );
    }

    #[test]
    fn heterogeneous_fleet_runs_all_dispatchers() {
        // Mixed co-tenant pressure: two full instances, two squeezed ones.
        let fleet = crate::server::coordinator::FleetSpec::parse(
            "2*llama3-8b@0.12,2*llama3-8b@0.04:128",
        )
        .unwrap();
        for disp in ["rr", "kairos", "oracle", "least"] {
            let res = run_fleet(fleet.clone().into(), "kairos", disp, trace(150, 4.0, 9));
            assert!(res.summary.n_workflows > 0, "{disp}: no workflows finished");
            assert!(res.summary.avg_token_latency.is_finite(), "{disp}");
        }
    }

    #[test]
    fn squeezed_fleet_slower_than_full_fleet() {
        // Same instance count, but half the fleet under heavy co-tenant
        // pressure must serve slower than a uniformly full fleet.
        let full = FleetSpec::parse("4*llama3-8b@0.12").unwrap();
        let squeezed = FleetSpec::parse("2*llama3-8b@0.12,2*llama3-8b@0.02").unwrap();
        let a = run_fleet(full.into(), "kairos", "kairos", trace(300, 8.0, 10));
        let b = run_fleet(squeezed.into(), "kairos", "kairos", trace(300, 8.0, 10));
        assert!(
            b.summary.avg_token_latency > a.summary.avg_token_latency,
            "squeezed {} !> full {}",
            b.summary.avg_token_latency,
            a.summary.avg_token_latency
        );
    }
}
