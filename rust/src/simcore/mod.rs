//! Discrete-event simulation core: a virtual clock and an event queue.
//!
//! The figure/bench harnesses run the whole serving system under virtual
//! time (thousands of simulated seconds per wall-clock second); the
//! quickstart/real mode uses the wall clock with the same engine code.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::Time;

/// A queued event: fires at `time`, carrying a payload. `seq` breaks ties
/// FIFO so simulation order is deterministic.
struct Entry<E> {
    time: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap on (time, seq). `total_cmp` keeps the
        // order total even for NaN times (which schedule() clamps away),
        // so heap invariants can never be corrupted by a bad key.
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Min-heap event queue with a monotonically advancing virtual clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    now: Time,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }

    /// Current virtual time (the time of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `payload` at absolute time `at`. Events scheduled in the
    /// past — or at NaN — are clamped to `now` (they fire immediately, in
    /// FIFO order), so the clock stays monotone no matter what a buggy
    /// cost model produces.
    pub fn schedule(&mut self, at: Time, payload: E) {
        let t = if at >= self.now { at } else { self.now };
        self.seq += 1;
        self.heap.push(Entry { time: t, seq: self.seq, payload });
    }

    /// Schedule `payload` after a delay relative to `now`.
    pub fn schedule_in(&mut self, delay: Time, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay");
        self.schedule(self.now + delay, payload);
    }

    /// Pop the next event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now, "time went backwards");
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.payload))
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.schedule(2.0, ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(t1, 2.0);
        assert_eq!(q.now(), 2.0);
        // Scheduling in the past clamps to now.
        q.schedule(1.0, ());
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2, 2.0);
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 5.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(10.0, "x");
        q.pop();
        q.schedule_in(5.0, "y");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 15.0);
    }

    #[test]
    fn nan_time_cannot_corrupt_heap_or_clock() {
        // Regression: Entry::cmp used partial_cmp(..).unwrap_or(Equal), so
        // a NaN time made the order non-total and could corrupt the heap.
        let mut q = EventQueue::new();
        q.schedule(2.0, "b");
        q.schedule(f64::NAN, "nan");
        q.schedule(1.0, "a");
        // NaN clamps to now (0.0): it fires first, and the clock stays a
        // real number throughout.
        let (t0, e0) = q.pop().unwrap();
        assert_eq!((t0, e0), (0.0, "nan"));
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b"]);
        assert!(q.now().is_finite());
    }

    #[test]
    fn empty_pop_is_none() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn processed_counter() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(i as f64, i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.processed(), 10);
    }
}
