//! Sampling distributions built on [`Rng`](super::rng::Rng).
//!
//! LogNormal models agent output lengths (paper Fig. 3 shows heavy-tailed,
//! roughly log-normal per-agent length distributions); Gamma mixtures model
//! bursty inter-arrival times; Exponential/Categorical support the workload
//! generator and branch decisions.

use super::rng::Rng;

/// A sampleable distribution over `f64`.
pub trait Dist {
    /// Draw one sample.
    fn sample(&self, rng: &mut Rng) -> f64;

    /// Analytic mean, if defined.
    fn mean(&self) -> f64;
}

/// Uniform over `[lo, hi)`.
#[derive(Debug, Clone, Copy)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi >= lo);
        Uniform { lo, hi }
    }
}

impl Dist for Uniform {
    fn sample(&self, rng: &mut Rng) -> f64 {
        self.lo + (self.hi - self.lo) * rng.f64()
    }
    fn mean(&self) -> f64 {
        0.5 * (self.lo + self.hi)
    }
}

/// Normal(mu, sigma) via Box–Muller (single-value variant).
#[derive(Debug, Clone, Copy)]
pub struct Normal {
    pub mu: f64,
    pub sigma: f64,
}

impl Normal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma >= 0.0);
        Normal { mu, sigma }
    }

    /// Standard normal sample.
    #[inline]
    pub fn std_sample(rng: &mut Rng) -> f64 {
        let u1 = rng.f64_open();
        let u2 = rng.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

impl Dist for Normal {
    fn sample(&self, rng: &mut Rng) -> f64 {
        self.mu + self.sigma * Normal::std_sample(rng)
    }
    fn mean(&self) -> f64 {
        self.mu
    }
}

/// LogNormal parameterized by the *underlying* normal's (mu, sigma).
///
/// `LogNormal::from_mean_cv` is the ergonomic constructor used by the
/// dataset models: specify the real-space mean and coefficient of variation.
#[derive(Debug, Clone, Copy)]
pub struct LogNormal {
    pub mu: f64,
    pub sigma: f64,
}

impl LogNormal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma >= 0.0);
        LogNormal { mu, sigma }
    }

    /// Construct from the real-space mean `m` and coefficient of variation
    /// `cv = std/mean`.
    pub fn from_mean_cv(m: f64, cv: f64) -> Self {
        assert!(m > 0.0 && cv >= 0.0);
        let sigma2 = (1.0 + cv * cv).ln();
        let mu = m.ln() - 0.5 * sigma2;
        LogNormal { mu, sigma: sigma2.sqrt() }
    }

    /// Real-space mode (highest-density point): `exp(mu - sigma^2)`.
    /// The paper's dispatcher uses the mode of the latency distribution as
    /// the expected execution time (§6).
    pub fn mode(&self) -> f64 {
        (self.mu - self.sigma * self.sigma).exp()
    }
}

impl Dist for LogNormal {
    fn sample(&self, rng: &mut Rng) -> f64 {
        (self.mu + self.sigma * Normal::std_sample(rng)).exp()
    }
    fn mean(&self) -> f64 {
        (self.mu + 0.5 * self.sigma * self.sigma).exp()
    }
}

/// Exponential with rate `lambda` (mean `1/lambda`).
#[derive(Debug, Clone, Copy)]
pub struct Exponential {
    pub lambda: f64,
}

impl Exponential {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0);
        Exponential { lambda }
    }
}

impl Dist for Exponential {
    fn sample(&self, rng: &mut Rng) -> f64 {
        -rng.f64_open().ln() / self.lambda
    }
    fn mean(&self) -> f64 {
        1.0 / self.lambda
    }
}

/// Gamma(shape k, scale theta) via Marsaglia–Tsang; k < 1 handled by the
/// boosting identity.
#[derive(Debug, Clone, Copy)]
pub struct Gamma {
    pub shape: f64,
    pub scale: f64,
}

impl Gamma {
    pub fn new(shape: f64, scale: f64) -> Self {
        assert!(shape > 0.0 && scale > 0.0);
        Gamma { shape, scale }
    }

    fn sample_shape_ge1(k: f64, rng: &mut Rng) -> f64 {
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = Normal::std_sample(rng);
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = rng.f64_open();
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }
}

impl Dist for Gamma {
    fn sample(&self, rng: &mut Rng) -> f64 {
        let k = self.shape;
        let raw = if k >= 1.0 {
            Gamma::sample_shape_ge1(k, rng)
        } else {
            // Gamma(k) = Gamma(k+1) * U^(1/k)
            Gamma::sample_shape_ge1(k + 1.0, rng) * rng.f64_open().powf(1.0 / k)
        };
        raw * self.scale
    }
    fn mean(&self) -> f64 {
        self.shape * self.scale
    }
}

/// Categorical over `0..weights.len()` with the given non-negative weights.
#[derive(Debug, Clone)]
pub struct Categorical {
    cumulative: Vec<f64>,
}

impl Categorical {
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty());
        assert!(weights.iter().all(|&w| w >= 0.0));
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0);
        let mut acc = 0.0;
        let cumulative = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Categorical { cumulative }
    }

    /// Draw an index.
    pub fn sample_index(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // cumulative is sorted; linear scan is fine for the small fans used.
        self.cumulative
            .iter()
            .position(|&c| u < c)
            .unwrap_or(self.cumulative.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stats(d: &impl Dist, n: usize, seed: u64) -> (f64, f64) {
        let mut rng = Rng::new(seed);
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        (mean, var)
    }

    #[test]
    fn uniform_mean() {
        let (m, _) = sample_stats(&Uniform::new(2.0, 6.0), 50_000, 1);
        assert!((m - 4.0).abs() < 0.05, "m={m}");
    }

    #[test]
    fn normal_moments() {
        let (m, v) = sample_stats(&Normal::new(3.0, 2.0), 100_000, 2);
        assert!((m - 3.0).abs() < 0.05, "m={m}");
        assert!((v - 4.0).abs() < 0.15, "v={v}");
    }

    #[test]
    fn lognormal_mean_matches_analytic() {
        let d = LogNormal::from_mean_cv(100.0, 0.8);
        let (m, _) = sample_stats(&d, 200_000, 3);
        assert!((m - 100.0).abs() / 100.0 < 0.03, "m={m}");
        assert!((d.mean() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn lognormal_mode_below_mean() {
        let d = LogNormal::from_mean_cv(100.0, 0.8);
        assert!(d.mode() < d.mean());
        assert!(d.mode() > 0.0);
    }

    #[test]
    fn lognormal_positive() {
        let d = LogNormal::from_mean_cv(10.0, 2.0);
        let mut rng = Rng::new(4);
        for _ in 0..10_000 {
            assert!(d.sample(&mut rng) > 0.0);
        }
    }

    #[test]
    fn exponential_mean() {
        let (m, _) = sample_stats(&Exponential::new(0.25), 100_000, 5);
        assert!((m - 4.0).abs() < 0.1, "m={m}");
    }

    #[test]
    fn gamma_mean_shape_ge1() {
        let (m, v) = sample_stats(&Gamma::new(4.0, 0.5), 100_000, 6);
        assert!((m - 2.0).abs() < 0.05, "m={m}");
        assert!((v - 1.0).abs() < 0.1, "v={v}"); // k*theta^2
    }

    #[test]
    fn gamma_mean_shape_lt1() {
        let (m, _) = sample_stats(&Gamma::new(0.5, 2.0), 200_000, 7);
        assert!((m - 1.0).abs() < 0.05, "m={m}");
    }

    #[test]
    fn categorical_frequencies() {
        let c = Categorical::new(&[1.0, 3.0, 6.0]);
        let mut rng = Rng::new(8);
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[c.sample_index(&mut rng)] += 1;
        }
        let freqs: Vec<f64> = counts.iter().map(|&c| c as f64 / n as f64).collect();
        assert!((freqs[0] - 0.1).abs() < 0.01);
        assert!((freqs[1] - 0.3).abs() < 0.01);
        assert!((freqs[2] - 0.6).abs() < 0.01);
    }

    #[test]
    #[should_panic]
    fn categorical_rejects_empty() {
        Categorical::new(&[]);
    }
}
