//! Empirical distributions and the Wasserstein-1 distance between them.
//!
//! The paper uses Wasserstein-1 twice: to test convergence of an agent's
//! latency distribution as samples double (§4.3), and as the pairwise
//! distance the MDS priority embedding is built from (§5.1).

/// An empirical CDF over collected samples (sorted on construction).
#[derive(Debug, Clone)]
pub struct Ecdf {
    sorted: Vec<f64>,
}

impl Ecdf {
    /// Build from samples; must be non-empty. The sort is total, so a
    /// stray NaN sample no longer panics the caller (the scheduler
    /// refresh builds these from live profiles); NaN of either sign sorts
    /// last (raw `total_cmp` would put negative NaN first and poison the
    /// low quantiles).
    pub fn new(mut samples: Vec<f64>) -> Ecdf {
        assert!(!samples.is_empty(), "ECDF needs at least one sample");
        samples.sort_by(|a, b| a.is_nan().cmp(&b.is_nan()).then(a.total_cmp(b)));
        Ecdf { sorted: samples }
    }

    /// The degenerate "ideal zero-latency" distribution (paper §5.1 anchor).
    pub fn zero() -> Ecdf {
        Ecdf { sorted: vec![0.0] }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        false // construction guarantees >= 1 sample
    }

    pub fn samples(&self) -> &[f64] {
        &self.sorted
    }

    pub fn mean(&self) -> f64 {
        self.sorted.iter().sum::<f64>() / self.sorted.len() as f64
    }

    /// Quantile by inverse-CDF with linear interpolation, `q` in `[0, 1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.sorted.len();
        if n == 1 {
            return self.sorted[0];
        }
        let rank = q.clamp(0.0, 1.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.sorted[lo] * (1.0 - frac) + self.sorted[hi] * frac
    }

    /// Real-space mode estimate: the densest point via a histogram over the
    /// sample range (the paper's "point with the highest probability
    /// density" used as the dispatcher's expected execution time, §6).
    pub fn mode(&self) -> f64 {
        let n = self.sorted.len();
        if n < 4 {
            return self.quantile(0.5);
        }
        let lo = self.sorted[0];
        let hi = *self.sorted.last().unwrap();
        if hi - lo < f64::EPSILON {
            return lo;
        }
        // Freedman–Diaconis-ish bin count, clamped.
        let bins = ((n as f64).sqrt().ceil() as usize).clamp(4, 64);
        let width = (hi - lo) / bins as f64;
        let mut counts = vec![0usize; bins];
        for &x in &self.sorted {
            let b = (((x - lo) / width) as usize).min(bins - 1);
            counts[b] += 1;
        }
        let best = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap();
        lo + (best as f64 + 0.5) * width
    }
}

/// A fixed-grid quantile sketch of an ECDF: `K` evenly spaced quantiles.
///
/// `W1(a, b) = ∫ |F⁻¹_a(q) − F⁻¹_b(q)| dq ≈ mean_k |sketch_a[k] − sketch_b[k]|`
/// — a branch-free O(K) distance used for the large pairwise matrices of
/// the priority update (§7.7 evaluates up to 5000 agents ⇒ 12.5M pairs;
/// the exact merge would dominate the refresh — EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    q: Vec<f64>,
}

impl QuantileSketch {
    pub const DEFAULT_K: usize = 64;

    pub fn of(ecdf: &Ecdf, k: usize) -> QuantileSketch {
        assert!(k >= 2);
        let q = (0..k)
            .map(|i| ecdf.quantile(i as f64 / (k - 1) as f64))
            .collect();
        QuantileSketch { q }
    }

    /// Sketch of the ideal zero-latency anchor.
    pub fn zero(k: usize) -> QuantileSketch {
        QuantileSketch { q: vec![0.0; k] }
    }

    /// Approximate Wasserstein-1 distance between two sketches.
    #[inline]
    pub fn w1(&self, other: &QuantileSketch) -> f64 {
        debug_assert_eq!(self.q.len(), other.q.len());
        let sum: f64 = self
            .q
            .iter()
            .zip(&other.q)
            .map(|(a, b)| (a - b).abs())
            .sum();
        sum / self.q.len() as f64
    }
}

/// Wasserstein-1 distance between two ECDFs: the integral of |F⁻¹_a − F⁻¹_b|
/// over quantiles, computed exactly via the merged-support formulation
/// `∫ |F_a(x) − F_b(x)| dx`.
pub fn wasserstein1(a: &Ecdf, b: &Ecdf) -> f64 {
    let xa = &a.sorted;
    let xb = &b.sorted;
    let (na, nb) = (xa.len() as f64, xb.len() as f64);
    let (mut ia, mut ib) = (0usize, 0usize);
    let mut dist = 0.0;
    let mut prev = f64::NAN;

    while ia < xa.len() || ib < xb.len() {
        let x = match (xa.get(ia), xb.get(ib)) {
            (Some(&va), Some(&vb)) => va.min(vb),
            (Some(&va), None) => va,
            (None, Some(&vb)) => vb,
            (None, None) => break,
        };
        if !prev.is_nan() && x > prev {
            let fa = ia as f64 / na;
            let fb = ib as f64 / nb;
            dist += (fa - fb).abs() * (x - prev);
        }
        while ia < xa.len() && xa[ia] <= x {
            ia += 1;
        }
        while ib < xb.len() && xb[ib] <= x {
            ib += 1;
        }
        prev = x;
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::dist::{Dist, LogNormal};
    use crate::stats::rng::Rng;

    fn ecdf_of(vals: &[f64]) -> Ecdf {
        Ecdf::new(vals.to_vec())
    }

    #[test]
    fn identity_distance_zero() {
        let a = ecdf_of(&[1.0, 2.0, 3.0]);
        assert!(wasserstein1(&a, &a) < 1e-12);
    }

    #[test]
    fn symmetry() {
        let a = ecdf_of(&[1.0, 2.0, 3.0, 10.0]);
        let b = ecdf_of(&[2.0, 2.5, 7.0]);
        assert!((wasserstein1(&a, &b) - wasserstein1(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn point_masses_distance_is_gap() {
        let a = ecdf_of(&[0.0]);
        let b = ecdf_of(&[5.0]);
        assert!((wasserstein1(&a, &b) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn shift_equals_offset() {
        // W1 between X and X + c is exactly c.
        let xs: Vec<f64> = (0..100).map(|i| i as f64 * 0.1).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x + 2.5).collect();
        let d = wasserstein1(&ecdf_of(&xs), &ecdf_of(&ys));
        assert!((d - 2.5).abs() < 1e-9, "d={d}");
    }

    #[test]
    fn triangle_inequality_on_samples() {
        let mut rng = Rng::new(17);
        let d1 = LogNormal::from_mean_cv(5.0, 0.5);
        let d2 = LogNormal::from_mean_cv(9.0, 0.9);
        let d3 = LogNormal::from_mean_cv(2.0, 0.3);
        let take = |d: &LogNormal, rng: &mut Rng| {
            Ecdf::new((0..200).map(|_| d.sample(rng)).collect())
        };
        let (a, b, c) = (take(&d1, &mut rng), take(&d2, &mut rng), take(&d3, &mut rng));
        let ab = wasserstein1(&a, &b);
        let bc = wasserstein1(&b, &c);
        let ac = wasserstein1(&a, &c);
        assert!(ac <= ab + bc + 1e-9);
    }

    #[test]
    fn distance_to_zero_anchor_orders_by_magnitude() {
        // Agents with larger remaining latency must be farther from the
        // zero anchor — the property Kairos' priority direction relies on.
        let zero = Ecdf::zero();
        let small = ecdf_of(&[1.0, 1.5, 2.0]);
        let large = ecdf_of(&[10.0, 15.0, 20.0]);
        assert!(wasserstein1(&small, &zero) < wasserstein1(&large, &zero));
    }

    #[test]
    fn quantiles() {
        let e = ecdf_of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((e.quantile(0.0) - 1.0).abs() < 1e-12);
        assert!((e.quantile(0.5) - 3.0).abs() < 1e-12);
        assert!((e.quantile(1.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn mode_finds_dense_region() {
        let mut vals = vec![10.0; 50];
        vals.extend((0..10).map(|i| 100.0 + i as f64));
        // jitter the dense cluster a bit
        for (i, v) in vals.iter_mut().enumerate().take(50) {
            *v += (i % 7) as f64 * 0.1;
        }
        let e = Ecdf::new(vals);
        let m = e.mode();
        assert!(m < 30.0, "mode should be near the dense cluster, got {m}");
    }

    #[test]
    fn lognormal_mode_estimate_close_to_analytic() {
        let d = LogNormal::from_mean_cv(10.0, 0.6);
        let mut rng = Rng::new(23);
        let e = Ecdf::new((0..20_000).map(|_| d.sample(&mut rng)).collect());
        let est = e.mode();
        let true_mode = d.mode();
        assert!(
            (est - true_mode).abs() / true_mode < 0.35,
            "est={est} true={true_mode}"
        );
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Ecdf::new(vec![]);
    }

    #[test]
    fn nan_sample_sorts_last_instead_of_panicking() {
        // Both NaN signs: the negative quiet NaN real 0.0/0.0 arithmetic
        // produces must not land FIRST (total_cmp orders by sign bit).
        let e = Ecdf::new(vec![1.0, f64::NAN, 0.5, -f64::NAN]);
        assert_eq!(e.len(), 4);
        assert_eq!(e.samples()[0], 0.5);
        assert_eq!(e.samples()[1], 1.0);
        assert!(e.samples()[2].is_nan());
        assert!(e.samples()[3].is_nan());
    }

    #[test]
    fn sketch_w1_close_to_exact() {
        let mut rng = Rng::new(31);
        let d1 = LogNormal::from_mean_cv(5.0, 0.7);
        let d2 = LogNormal::from_mean_cv(12.0, 0.9);
        let a = Ecdf::new((0..500).map(|_| d1.sample(&mut rng)).collect());
        let b = Ecdf::new((0..500).map(|_| d2.sample(&mut rng)).collect());
        let exact = wasserstein1(&a, &b);
        let sa = QuantileSketch::of(&a, QuantileSketch::DEFAULT_K);
        let sb = QuantileSketch::of(&b, QuantileSketch::DEFAULT_K);
        let approx = sa.w1(&sb);
        assert!(
            (approx - exact).abs() / exact < 0.1,
            "approx={approx} exact={exact}"
        );
    }

    #[test]
    fn sketch_anchor_distance_orders_by_mean() {
        let small = Ecdf::new(vec![1.0, 2.0, 3.0]);
        let large = Ecdf::new(vec![10.0, 20.0, 30.0]);
        let z = QuantileSketch::zero(16);
        let ds = QuantileSketch::of(&small, 16).w1(&z);
        let dl = QuantileSketch::of(&large, 16).w1(&z);
        assert!(ds < dl);
    }

    #[test]
    fn sketch_self_distance_zero() {
        let a = Ecdf::new(vec![1.0, 5.0, 9.0]);
        let s = QuantileSketch::of(&a, 32);
        assert!(s.w1(&s) < 1e-12);
    }
}
