//! Kendall rank correlation — used by the Fig. 8 analysis to quantify how
//! well a scheduling order tracks true inference latency.

/// Kendall's tau-a over paired observations (O(n²), fine for analysis sizes).
///
/// Returns a value in `[-1, 1]`; 1 means the orders agree perfectly, 0 means
/// no association (what FCFS produces between queue position and latency).
pub fn kendall_tau(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..n {
        for j in (i + 1)..n {
            let dx = xs[i] - xs[j];
            let dy = ys[i] - ys[j];
            let s = dx * dy;
            if s > 0.0 {
                concordant += 1;
            } else if s < 0.0 {
                discordant += 1;
            }
        }
    }
    let pairs = (n * (n - 1) / 2) as f64;
    (concordant - discordant) as f64 / pairs
}

/// Pairwise sorting accuracy (paper §7.4): the proportion of pairs whose
/// relative order in `order` (smaller = scheduled earlier) matches the order
/// of their true remaining latencies `latency`. Ties in either count as half.
pub fn pairwise_sorting_accuracy(order: &[f64], latency: &[f64]) -> f64 {
    assert_eq!(order.len(), latency.len());
    let n = order.len();
    if n < 2 {
        return 1.0;
    }
    let mut correct = 0.0;
    let mut total = 0.0;
    for i in 0..n {
        for j in (i + 1)..n {
            total += 1.0;
            let do_ = order[i] - order[j];
            let dl = latency[i] - latency[j];
            if do_ == 0.0 || dl == 0.0 {
                correct += 0.5;
            } else if do_ * dl > 0.0 {
                correct += 1.0;
            }
        }
    }
    correct / total
}

/// Pairwise sorting accuracy restricted to pairs from DIFFERENT groups
/// (the paper's §7.4 measure compares each request "with all other agent
/// requests" — inter-agent pairs, which is what agent-level priorities can
/// order). Ties count half.
pub fn pairwise_sorting_accuracy_grouped(
    order: &[f64],
    latency: &[f64],
    group: &[u32],
) -> f64 {
    assert_eq!(order.len(), latency.len());
    assert_eq!(order.len(), group.len());
    let n = order.len();
    let mut correct = 0.0;
    let mut total = 0.0;
    for i in 0..n {
        for j in (i + 1)..n {
            if group[i] == group[j] {
                continue;
            }
            total += 1.0;
            let do_ = order[i] - order[j];
            let dl = latency[i] - latency[j];
            if do_ == 0.0 || dl == 0.0 {
                correct += 0.5;
            } else if do_ * dl > 0.0 {
                correct += 1.0;
            }
        }
    }
    if total == 0.0 {
        1.0
    } else {
        correct / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouped_accuracy_ignores_same_group_pairs() {
        // Two groups; within-group order is wrong but cross-group is right.
        let order = [0.0, 1.0, 2.0, 3.0];
        let latency = [2.0, 1.0, 9.0, 8.0]; // within-group inverted
        let group = [0u32, 0, 1, 1];
        let acc = pairwise_sorting_accuracy_grouped(&order, &latency, &group);
        assert!((acc - 1.0).abs() < 1e-12, "acc={acc}");
    }

    #[test]
    fn grouped_accuracy_all_same_group_is_one() {
        let acc = pairwise_sorting_accuracy_grouped(&[1.0, 2.0], &[5.0, 1.0], &[0, 0]);
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn perfect_agreement() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((kendall_tau(&xs, &xs) - 1.0).abs() < 1e-12);
        assert!((pairwise_sorting_accuracy(&xs, &xs) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn perfect_disagreement() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [4.0, 3.0, 2.0, 1.0];
        assert!((kendall_tau(&xs, &ys) + 1.0).abs() < 1e-12);
        assert!(pairwise_sorting_accuracy(&xs, &ys) < 1e-12);
    }

    #[test]
    fn random_near_zero() {
        use crate::stats::rng::Rng;
        let mut rng = Rng::new(99);
        let xs: Vec<f64> = (0..500).map(|_| rng.f64()).collect();
        let ys: Vec<f64> = (0..500).map(|_| rng.f64()).collect();
        assert!(kendall_tau(&xs, &ys).abs() < 0.1);
        assert!((pairwise_sorting_accuracy(&xs, &ys) - 0.5).abs() < 0.05);
    }

    #[test]
    fn ties_count_half() {
        let order = [1.0, 1.0];
        let lat = [3.0, 5.0];
        assert!((pairwise_sorting_accuracy(&order, &lat) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn short_inputs() {
        assert_eq!(kendall_tau(&[], &[]), 0.0);
        assert_eq!(pairwise_sorting_accuracy(&[1.0], &[2.0]), 1.0);
    }
}
