//! Classical multidimensional scaling (MDS) to one dimension.
//!
//! The paper (§5.1) embeds the agents' pairwise Wasserstein distance matrix
//! into a 1-D coordinate space with MDS and orients the axis with an ideal
//! "zero latency" anchor distribution. Classical (Torgerson) MDS to 1-D is
//! the dominant eigenvector of the double-centered squared-distance matrix,
//! scaled by sqrt of the dominant eigenvalue; we compute it with a cyclic
//! Jacobi eigensolver (no external linear algebra crates on this image).

/// Dense symmetric matrix stored row-major.
#[derive(Debug, Clone)]
pub struct SymMatrix {
    pub n: usize,
    pub data: Vec<f64>,
}

impl SymMatrix {
    pub fn zeros(n: usize) -> Self {
        SymMatrix { n, data: vec![0.0; n * n] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
        self.data[j * self.n + i] = v;
    }
}

/// Dominant eigenpair of a symmetric matrix via power iteration with
/// Rayleigh-quotient convergence. Returns `(eigenvalue, eigenvector)`.
///
/// Power iteration converges to the eigenvalue of largest magnitude; for the
/// double-centered MDS Gram matrix the dominant eigenvalue is the one we
/// want (it is positive whenever the distances carry any 1-D signal).
pub fn dominant_eigen(m: &SymMatrix, max_iter: usize, tol: f64) -> (f64, Vec<f64>) {
    let n = m.n;
    assert!(n > 0);
    // Deterministic, not-axis-aligned start.
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin() * 0.5).collect();
    normalize(&mut v);
    let mut lambda = 0.0;
    for _ in 0..max_iter {
        let mut w = vec![0.0; n];
        for i in 0..n {
            let row = &m.data[i * n..(i + 1) * n];
            w[i] = row.iter().zip(&v).map(|(a, b)| a * b).sum();
        }
        let new_lambda: f64 = v.iter().zip(&w).map(|(a, b)| a * b).sum();
        let norm = normalize(&mut w);
        if norm < 1e-300 {
            return (0.0, v); // matrix annihilated the iterate: zero spectrum
        }
        let done = (new_lambda - lambda).abs() <= tol * new_lambda.abs().max(1.0);
        v = w;
        lambda = new_lambda;
        if done {
            break;
        }
    }
    (lambda, v)
}

fn normalize(v: &mut [f64]) -> f64 {
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    norm
}

/// Classical MDS of a distance matrix to 1-D.
///
/// Returns one coordinate per point. Coordinates are centered (mean 0) and
/// defined up to sign — callers orient the axis themselves (Kairos uses the
/// zero-latency anchor's coordinate; see [`mds_1d_anchored`]).
pub fn mds_1d(dist: &SymMatrix) -> Vec<f64> {
    let n = dist.n;
    if n == 0 {
        return vec![];
    }
    if n == 1 {
        return vec![0.0];
    }
    // B = -1/2 * J D^2 J  (double centering)
    let mut d2 = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let d = dist.get(i, j);
            d2[i * n + j] = d * d;
        }
    }
    let row_means: Vec<f64> = (0..n)
        .map(|i| d2[i * n..(i + 1) * n].iter().sum::<f64>() / n as f64)
        .collect();
    let grand = row_means.iter().sum::<f64>() / n as f64;
    let mut b = SymMatrix::zeros(n);
    for i in 0..n {
        for j in i..n {
            let v = -0.5 * (d2[i * n + j] - row_means[i] - row_means[j] + grand);
            b.set(i, j, v);
        }
    }
    // A 1-D ranking only needs the eigenvector's *order* to stabilize;
    // 1e-9 relative tolerance and a bounded iteration count keep large-n
    // updates within the paper's §7.7 envelope (EXPERIMENTS.md §Perf).
    let max_iter = if n >= 1000 { 120 } else { 500 };
    let (lambda, vec) = dominant_eigen(&b, max_iter, 1e-9);
    let scale = lambda.max(0.0).sqrt();
    vec.into_iter().map(|x| x * scale).collect()
}

/// MDS embedding of `dists` (size n+1, the LAST row/column being the anchor
/// point), oriented so that the anchor sits at the minimum of the axis.
///
/// Returns the coordinates of the n non-anchor points, oriented so *smaller
/// coordinate = closer to the anchor = shorter remaining latency = higher
/// priority* (paper §5.1).
pub fn mds_1d_anchored(dists: &SymMatrix) -> Vec<f64> {
    let n1 = dists.n;
    assert!(n1 >= 2, "need at least one point plus the anchor");
    let coords = mds_1d(dists);
    let anchor = coords[n1 - 1];
    let mean_others =
        coords[..n1 - 1].iter().sum::<f64>() / (n1 - 1) as f64;
    // Flip so the anchor is on the low side of the others' mean.
    let flip = anchor > mean_others;
    coords[..n1 - 1]
        .iter()
        .map(|&c| {
            let c = if flip { -c } else { c };
            let a = if flip { -anchor } else { anchor };
            c - a // anchor at 0, others >= ~0
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dist_matrix(points: &[f64]) -> SymMatrix {
        let n = points.len();
        let mut m = SymMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                m.set(i, j, (points[i] - points[j]).abs());
            }
        }
        m
    }

    #[test]
    fn recovers_line_up_to_sign_and_shift() {
        let pts = [0.0, 1.0, 3.0, 7.0, 12.0];
        let coords = mds_1d(&dist_matrix(&pts));
        // Pairwise distances must be preserved.
        for i in 0..pts.len() {
            for j in 0..pts.len() {
                let want = (pts[i] - pts[j]).abs();
                let got = (coords[i] - coords[j]).abs();
                assert!((want - got).abs() < 1e-6, "({i},{j}): want {want} got {got}");
            }
        }
    }

    #[test]
    fn ordering_preserved_up_to_reversal() {
        let pts = [2.0, 9.0, 4.0, 0.5];
        let coords = mds_1d(&dist_matrix(&pts));
        let mut idx: Vec<usize> = (0..4).collect();
        idx.sort_by(|&a, &b| coords[a].total_cmp(&coords[b]));
        let fwd = vec![3usize, 0, 2, 1];
        let rev: Vec<usize> = fwd.iter().rev().cloned().collect();
        assert!(idx == fwd || idx == rev, "idx={idx:?}");
    }

    #[test]
    fn anchored_orientation_puts_zero_lowest() {
        // Points at 3, 8, 1 plus anchor at 0 (last row).
        let pts = [3.0, 8.0, 1.0, 0.0];
        let coords = mds_1d_anchored(&dist_matrix(&pts));
        assert_eq!(coords.len(), 3);
        // Orientation: point closest to the anchor gets the smallest coord.
        assert!(coords[2] < coords[0] && coords[0] < coords[1], "{coords:?}");
        // Anchor normalized to ~0 => all others non-negative.
        assert!(coords.iter().all(|&c| c > -1e-6));
    }

    #[test]
    fn single_point_with_anchor() {
        let pts = [5.0, 0.0];
        let coords = mds_1d_anchored(&dist_matrix(&pts));
        assert_eq!(coords.len(), 1);
        assert!((coords[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn identical_points_collapse() {
        let m = SymMatrix::zeros(4);
        let coords = mds_1d(&m);
        assert!(coords.iter().all(|&c| c.abs() < 1e-9));
    }

    #[test]
    fn dominant_eigen_of_diag() {
        let mut m = SymMatrix::zeros(3);
        m.set(0, 0, 1.0);
        m.set(1, 1, 5.0);
        m.set(2, 2, 2.0);
        let (l, v) = dominant_eigen(&m, 1000, 1e-14);
        assert!((l - 5.0).abs() < 1e-6, "l={l}");
        assert!(v[1].abs() > 0.99);
    }
}
