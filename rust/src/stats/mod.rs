//! Statistics substrate: PRNG, distributions, ECDFs, Wasserstein-1 distance,
//! classical MDS, rank correlation and streaming summaries.
//!
//! The offline toolchain ships no `rand`/`statrs`/`nalgebra`, so everything
//! here is implemented from scratch (DESIGN.md §3). These primitives are the
//! mathematical core of the paper: the scheduler's agent priorities are
//! `Wasserstein-1 → distance matrix → MDS → 1-D ranking` (paper §5.1) and the
//! dispatcher's expected execution times are distribution modes (paper §6).

pub mod dist;
pub mod ecdf;
pub mod kendall;
pub mod mds;
pub mod rng;
pub mod summary;

pub use dist::{Categorical, Dist, Exponential, Gamma, LogNormal, Normal, Uniform};
pub use ecdf::Ecdf;
pub use kendall::kendall_tau;
pub use mds::mds_1d;
pub use rng::Rng;
pub use summary::Summary;
