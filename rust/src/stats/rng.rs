//! Deterministic PRNG: splitmix64 seeding + xoshiro256**.
//!
//! Every stochastic component in the system (workload arrivals, dataset
//! output lengths, branch decisions) takes an explicit [`Rng`] so whole
//! experiments replay bit-identically from a seed.

/// xoshiro256** with splitmix64 seeding. Not cryptographic; fast and with
/// good statistical quality for simulation purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-component streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe as a `ln()` argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free multiply-shift (tiny bias acceptable
        // for simulation at n << 2^64).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(3);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Rng::new(5);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
