//! Latency summaries: mean + percentile statistics over sample sets.

/// Summary statistics of a sample set. Construction sorts a copy once; all
/// queries are O(1) afterwards.
#[derive(Debug, Clone)]
pub struct Summary {
    sorted: Vec<f64>,
    mean: f64,
}

impl Summary {
    /// Build from raw samples. Returns `None` for an empty input. The sort
    /// is total: a NaN sample (e.g. from a degenerate latency record)
    /// sorts last — either sign; raw `total_cmp` would put negative NaN
    /// first — instead of panicking mid-report.
    pub fn from_samples(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.is_nan().cmp(&b.is_nan()).then(a.total_cmp(b)));
        let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
        Some(Summary { sorted, mean })
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap()
    }

    /// Linear-interpolated percentile, `p` in `[0, 100]`.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.sorted.len();
        if n == 1 {
            return self.sorted[0];
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.sorted[lo] * (1.0 - frac) + self.sorted[hi] * frac
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p90(&self) -> f64 {
        self.percentile(90.0)
    }
    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean;
        (self.sorted.iter().map(|x| (x - m).powi(2)).sum::<f64>() / self.len() as f64)
            .sqrt()
    }
}

/// Streaming mean/variance (Welford) for O(1)-memory monitoring counters.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_none() {
        assert!(Summary::from_samples(&[]).is_none());
    }

    #[test]
    fn single_sample() {
        let s = Summary::from_samples(&[5.0]).unwrap();
        assert_eq!(s.mean(), 5.0);
        assert_eq!(s.p99(), 5.0);
        assert_eq!(s.min(), 5.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn percentiles_of_known_set() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = Summary::from_samples(&xs).unwrap();
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.p90() - 90.1).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_monotone() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let s = Summary::from_samples(&xs).unwrap();
        let mut prev = f64::NEG_INFINITY;
        for p in 0..=100 {
            let v = s.percentile(p as f64);
            assert!(v >= prev);
            prev = v;
        }
    }

    #[test]
    fn nan_sample_does_not_panic() {
        let s = Summary::from_samples(&[2.0, f64::NAN, 1.0, -f64::NAN]).unwrap();
        assert_eq!(s.min(), 1.0, "negative NaN must not displace the min");
        assert!(s.max().is_nan(), "NaN sorts last");
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - 5.0).abs() < 1e-12);
        assert!((o.std() - 2.0).abs() < 1e-12);
        assert_eq!(o.count(), 8);
    }
}
