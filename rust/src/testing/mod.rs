//! Mini property-testing runner (proptest is unavailable offline).
//!
//! `forall` drives a property over many generated cases and, on failure,
//! reports the seed of the failing case so it can be replayed exactly.

use crate::stats::rng::Rng;

/// Run `prop` over `cases` generated inputs. `gen` builds an input from an
/// [`Rng`]; `prop` returns `Err(description)` on violation. Panics with the
/// failing case's seed embedded in the message.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    base_seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed on case {case} (replay seed {seed}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Like [`forall`] but the property receives a fresh Rng too (for properties
/// that are themselves randomized).
pub fn forall_with_rng<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    base_seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T, &mut Rng) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        let mut prop_rng = rng.fork(0xF00D);
        if let Err(msg) = prop(&input, &mut prop_rng) {
            panic!(
                "property `{name}` failed on case {case} (replay seed {seed}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            "always-true",
            50,
            1,
            |rng| rng.below(10),
            |_| {
                // count via closure side effect is not possible with Fn; use
                // a cell
                Ok(())
            },
        );
        // separate check that generation is deterministic per seed
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        for _ in 0..10 {
            assert_eq!(r1.below(100), r2.below(100));
            count += 1;
        }
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        forall(
            "fails",
            10,
            2,
            |rng| rng.below(10),
            |&x| {
                if x < 10 {
                    Err("x is always < 10".into())
                } else {
                    Ok(())
                }
            },
        );
    }
}
