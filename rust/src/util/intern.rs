//! Process-wide string interner: one leaked allocation per unique string.
//!
//! Agent names flow through two paths that both need `'static` strings —
//! the trace recorder's [`crate::workload::trace::StageRecord`] and the
//! orchestrator's [`crate::orchestrator::AgentRegistry`]. Both delegate
//! here so a name submitted through either path is leaked at most once
//! for the life of the process, and equal names always share one
//! allocation (pointer equality holds across the two paths).

use std::collections::HashSet;
use std::sync::{Mutex, OnceLock};

/// Intern `s`, leaking it on first sight and returning the shared
/// `'static` copy afterwards. Safe to call from any thread.
pub fn intern(s: &str) -> &'static str {
    static POOL: OnceLock<Mutex<HashSet<&'static str>>> = OnceLock::new();
    let pool = POOL.get_or_init(|| Mutex::new(HashSet::new()));
    let mut guard = match pool.lock() {
        Ok(g) => g,
        // A panic while holding the lock cannot leave the set in a bad
        // state (insert-only); keep serving rather than propagating.
        Err(poisoned) => poisoned.into_inner(),
    };
    if let Some(&k) = guard.get(s) {
        return k;
    }
    let leaked: &'static str = Box::leak(s.to_string().into_boxed_str());
    guard.insert(leaked);
    leaked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_strings_share_one_allocation() {
        let a = intern("bench-pressure-agent");
        let b = intern(&String::from("bench-pressure-agent"));
        assert_eq!(a, b);
        assert!(std::ptr::eq(a, b), "same name must intern to same pointer");
    }

    #[test]
    fn distinct_strings_stay_distinct() {
        let a = intern("intern-a");
        let b = intern("intern-b");
        assert_ne!(a, b);
        assert!(!std::ptr::eq(a, b));
    }

    #[test]
    fn usable_across_threads() {
        let handles: Vec<_> = (0..4)
            .map(|_| std::thread::spawn(|| intern("intern-threaded")))
            .collect();
        let mut ptrs = Vec::new();
        for h in handles {
            match h.join() {
                Ok(p) => ptrs.push(p),
                Err(e) => std::panic::resume_unwind(e),
            }
        }
        for p in &ptrs {
            assert!(std::ptr::eq(*p, ptrs[0]));
        }
    }
}
