//! Minimal JSON: enough to read the AOT manifests and write result files.
//! (serde is not available offline; see DESIGN.md §3.)

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Strict non-negative integer view: `Some` only for whole numbers
    /// representable without loss (unlike [`Json::as_usize`], which
    /// truncates). Trace-file token counts go through this so `1.5` is a
    /// parse error, not a silent truncation.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n <= (1u64 << 53) as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("short \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
            "name": "tiny", "batch": 4, "max_seq": 64,
            "kv_cache_shape": [2, 2, 4, 64, 4, 16],
            "outputs": ["logits", "next_token", "kv_cache"]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("tiny"));
        assert_eq!(j.get("batch").unwrap().as_usize(), Some(4));
        let shape: Vec<usize> = j
            .get("kv_cache_shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![2, 2, 4, 64, 4, 16]);
    }

    #[test]
    fn round_trips() {
        let j = Json::obj(vec![
            ("a", Json::from(1.5)),
            ("b", Json::from("hi \"there\"\n")),
            ("c", Json::from(vec![1usize, 2, 3])),
            ("d", Json::Null),
            ("e", Json::from(true)),
        ]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn as_u64_is_strict_about_integrality() {
        assert_eq!(Json::Num(42.0).as_u64(), Some(42));
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Num(1.5).as_u64(), None, "no truncation");
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(f64::NAN).as_u64(), None);
        assert_eq!(Json::Num(1e300).as_u64(), None, "out of exact range");
        assert_eq!(Json::Str("42".into()).as_u64(), None);
    }

    #[test]
    fn nested_structures() {
        let j = Json::parse(r#"{"a": {"b": [{"c": -1.5e2}]}}"#).unwrap();
        let c = j.get("a").unwrap().get("b").unwrap().as_arr().unwrap()[0]
            .get("c")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(c, -150.0);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}
