//! Small utilities: a minimal JSON parser/writer (no serde on this image),
//! CSV output, and aligned table printing for the figure harnesses.

pub mod csv;
pub mod json;
pub mod table;

pub use json::Json;
pub use table::Table;
