//! Small utilities: a minimal JSON parser/writer (no serde on this image),
//! CSV output, aligned table printing for the figure harnesses, the
//! process-wide string interner, and the bounded ring-buffer log behind
//! the coordinator's `LogConfig`.

pub mod csv;
pub mod intern;
pub mod json;
pub mod ring;
pub mod table;

pub use intern::intern;
pub use json::Json;
pub use ring::RingLog;
pub use table::Table;
