//! Bounded ring-buffer log: `Vec`-compatible append semantics with an
//! optional retention cap.
//!
//! The coordinator's decision logs (`dispatch_log`, `group_log`,
//! `route_log`, `trace_log`) grow by one entry per request. For the seam
//! tests and the replay toolchain that is the point — the full log IS the
//! contract — but a million-request bench run has no reader for a
//! million-entry `Vec<GroupDispatch>` and pays allocation and resident
//! memory for it anyway. [`RingLog`] keeps the append API and, when a cap
//! is set, retains only the newest `cap` entries while still counting every
//! append in [`RingLog::total`]. Unbounded (the default) it behaves exactly
//! like the `Vec` it replaces: nothing is ever evicted and `len == total`.
//!
//! Eviction drops *retention*, never *behavior*: the coordinator pushes the
//! same entries in the same order regardless of the cap, a contract pinned
//! by the ring-buffer seam test in `tests/runtime_seam.rs`.

/// An append-only log with an optional bound on retained entries.
///
/// With `cap = None` this is a plain `Vec` (the default, and what every
/// existing test and sweep sees). With `cap = Some(k)` only the newest `k`
/// entries are kept; older entries are overwritten in place, so a
/// million-append run holds at most `k` live entries.
#[derive(Debug, Clone)]
pub struct RingLog<T> {
    buf: Vec<T>,
    /// Index of the oldest retained entry (0 until the ring wraps).
    start: usize,
    /// Retention cap; `None` = unbounded.
    cap: Option<usize>,
    /// Entries ever appended (retained or not).
    total: u64,
}

impl<T> Default for RingLog<T> {
    fn default() -> Self {
        RingLog::new()
    }
}

impl<T> RingLog<T> {
    /// An unbounded log — exact `Vec` semantics.
    pub fn new() -> RingLog<T> {
        RingLog { buf: Vec::new(), start: 0, cap: None, total: 0 }
    }

    /// A log retaining only the newest `cap` entries (`cap = 0` counts
    /// appends but retains nothing).
    pub fn bounded(cap: usize) -> RingLog<T> {
        RingLog { buf: Vec::new(), start: 0, cap: Some(cap), total: 0 }
    }

    /// Change the retention cap in place, evicting oldest entries if the
    /// new cap is smaller than the current retained count.
    pub fn set_cap(&mut self, cap: Option<usize>) {
        self.buf.rotate_left(self.start);
        self.start = 0;
        self.cap = cap;
        if let Some(c) = cap {
            if self.buf.len() > c {
                self.buf.drain(..self.buf.len() - c);
            }
        }
    }

    pub fn cap(&self) -> Option<usize> {
        self.cap
    }

    /// Append one entry, evicting the oldest retained entry when at cap.
    pub fn push(&mut self, value: T) {
        self.total += 1;
        match self.cap {
            None => self.buf.push(value),
            Some(0) => {}
            Some(c) => {
                if self.buf.len() < c {
                    self.buf.push(value);
                } else {
                    self.buf[self.start] = value;
                    self.start = (self.start + 1) % c;
                }
            }
        }
    }

    /// Retained entries (`== total` when unbounded).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Entries ever appended, including evicted ones. This is the log's
    /// stream position: fields like `ScaleEvent::dispatch_seq` record it so
    /// cross-log ordering survives eviction.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Entries appended but no longer retained.
    pub fn evicted(&self) -> u64 {
        self.total - self.buf.len() as u64
    }

    /// Retained entries, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.buf[self.start..].iter().chain(self.buf[..self.start].iter())
    }

    /// The `i`-th retained entry in chronological order.
    pub fn get(&self, i: usize) -> Option<&T> {
        if i >= self.buf.len() {
            return None;
        }
        // When wrapped the buffer is full (len == cap), so indexing is
        // modular; before wrapping start == 0.
        let idx = (self.start + i) % self.buf.len();
        self.buf.get(idx)
    }

    /// The newest entry.
    pub fn last(&self) -> Option<&T> {
        if self.buf.is_empty() {
            None
        } else if self.start == 0 {
            self.buf.last()
        } else {
            self.buf.get(self.start - 1)
        }
    }

    /// Drain the retained entries into a chronological `Vec`, resetting the
    /// log (total included) — the bounded analogue of `std::mem::take` on a
    /// `Vec` log, used when a run hands its logs to a `SimResult`.
    pub fn take_vec(&mut self) -> Vec<T> {
        let mut out = std::mem::take(&mut self.buf);
        out.rotate_left(self.start);
        self.start = 0;
        self.total = 0;
        out
    }

    /// Shallow resident bytes of the retained buffer (capacity, not len —
    /// the high-water mark of what this log pins in memory). Per-entry heap
    /// (e.g. a record's inner `Vec`) is not included.
    pub fn approx_bytes(&self) -> usize {
        self.buf.capacity() * std::mem::size_of::<T>()
    }
}

impl<T: Clone> RingLog<T> {
    /// Retained entries as a chronological `Vec` (non-destructive).
    pub fn to_vec(&self) -> Vec<T> {
        self.iter().cloned().collect()
    }
}

impl<'a, T> IntoIterator for &'a RingLog<T> {
    type Item = &'a T;
    type IntoIter =
        std::iter::Chain<std::slice::Iter<'a, T>, std::slice::Iter<'a, T>>;

    /// `for x in &log` iterates retained entries oldest-first, mirroring
    /// iteration over the `Vec` this type replaces.
    fn into_iter(self) -> Self::IntoIter {
        self.buf[self.start..].iter().chain(self.buf[..self.start].iter())
    }
}

impl<T> std::ops::Index<usize> for RingLog<T> {
    type Output = T;

    /// Chronological indexing over *retained* entries (`log[0]` is the
    /// oldest retained entry, not append number 0 once eviction starts).
    fn index(&self, i: usize) -> &T {
        self.get(i)
            .unwrap_or_else(|| panic!("RingLog index {i} out of bounds"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_matches_vec_semantics() {
        let mut log = RingLog::new();
        for i in 0..100 {
            log.push(i);
        }
        assert_eq!(log.len(), 100);
        assert_eq!(log.total(), 100);
        assert_eq!(log.evicted(), 0);
        assert_eq!(log.get(0), Some(&0));
        assert_eq!(log.last(), Some(&99));
        let v = log.take_vec();
        assert_eq!(v, (0..100).collect::<Vec<_>>());
        assert_eq!(log.len(), 0);
        assert_eq!(log.total(), 0);
    }

    #[test]
    fn bounded_retains_newest_in_order() {
        let mut log = RingLog::bounded(4);
        for i in 0..10 {
            log.push(i);
        }
        assert_eq!(log.len(), 4);
        assert_eq!(log.total(), 10);
        assert_eq!(log.evicted(), 6);
        assert_eq!(log.iter().copied().collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert_eq!(log.get(0), Some(&6));
        assert_eq!(log.get(3), Some(&9));
        assert_eq!(log.get(4), None);
        assert_eq!(log[0], 6);
        let mut via_ref = Vec::new();
        for &x in &log {
            via_ref.push(x);
        }
        assert_eq!(via_ref, vec![6, 7, 8, 9]);
        assert_eq!(log.last(), Some(&9));
        assert_eq!(log.take_vec(), vec![6, 7, 8, 9]);
        assert!(log.is_empty());
    }

    #[test]
    fn bounded_before_wrapping_behaves_like_vec() {
        let mut log = RingLog::bounded(8);
        for i in 0..5 {
            log.push(i);
        }
        assert_eq!(log.len(), 5);
        assert_eq!(log.last(), Some(&4));
        assert_eq!(log.iter().copied().collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn zero_cap_counts_but_retains_nothing() {
        let mut log = RingLog::bounded(0);
        for i in 0..5 {
            log.push(i);
        }
        assert!(log.is_empty());
        assert_eq!(log.total(), 5);
        assert_eq!(log.last(), None);
        assert_eq!(log.take_vec(), Vec::<i32>::new());
    }

    #[test]
    fn set_cap_evicts_oldest_and_keeps_order() {
        let mut log = RingLog::bounded(4);
        for i in 0..10 {
            log.push(i); // retained: [6, 7, 8, 9], wrapped
        }
        log.set_cap(Some(2));
        assert_eq!(log.iter().copied().collect::<Vec<_>>(), vec![8, 9]);
        assert_eq!(log.total(), 10);
        log.push(10);
        assert_eq!(log.iter().copied().collect::<Vec<_>>(), vec![9, 10]);
        // Raising the cap (or removing it) keeps everything retained.
        log.set_cap(None);
        log.push(11);
        assert_eq!(log.iter().copied().collect::<Vec<_>>(), vec![9, 10, 11]);
    }

    #[test]
    fn bounded_memory_stays_at_cap() {
        let mut log = RingLog::bounded(16);
        for i in 0..100_000u64 {
            log.push(i);
        }
        assert!(log.approx_bytes() <= 16 * std::mem::size_of::<u64>());
        assert_eq!(log.len(), 16);
        assert_eq!(log.total(), 100_000);
    }
}
