//! Aligned plain-text tables — the figure harnesses print the paper's rows
//! with these.

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(c);
                line.push_str(&" ".repeat(width[i] - c.len()));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with 3 significant decimals for table cells.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
        // columns aligned: "value" column starts at same offset
        let col = lines[0].find("value").unwrap();
        assert_eq!(&lines[2][col..col + 1], "1");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f3(1.23456), "1.235");
        assert_eq!(pct(0.285), "28.5%");
    }
}
