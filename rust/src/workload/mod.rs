//! Workload generation (paper §7.1 "Loads").
//!
//! The paper replays a production LLM inference trace (Splitwise [41]),
//! proportionally scaled so the queueing-time ratio spans 0–90%. The trace
//! itself is not public, so we generate arrivals with the property that
//! matters: **burstiness**. Inter-arrival gaps are Gamma-distributed with
//! shape < 1 (CV ≈ 1.8, matching the reported heavy burst structure of
//! production LLM traces), scaled to a target mean rate.

pub mod trace;

pub use trace::{FileSource, GenSource, StageRecord, Trace, TraceRecord, TraceSource};

use crate::agents::apps::{App, WorkflowPlan};
use crate::stats::dist::{Dist, Gamma};
use crate::stats::rng::Rng;
use crate::Time;

/// Mix of applications in a workload.
#[derive(Debug, Clone)]
pub struct WorkloadMix {
    /// (app, dataset, weight)
    pub entries: Vec<(App, &'static str, f64)>,
}

impl WorkloadMix {
    /// Single application on one dataset (§7.2 experiments).
    pub fn single(app: App, dataset: &'static str) -> WorkloadMix {
        WorkloadMix { entries: vec![(app, dataset, 1.0)] }
    }

    /// The co-located workload (§7.3): QA/G+M + RG/TQ + CG/HE, equal share.
    pub fn colocated() -> WorkloadMix {
        WorkloadMix {
            entries: vec![
                (App::Qa, "G+M", 1.0),
                (App::Rg, "TQ", 1.0),
                (App::Cg, "HE", 1.0),
            ],
        }
    }
}

/// One arriving user task.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalEvent {
    pub at: Time,
    pub plan: WorkflowPlan,
    /// Prefix-cache session key override carried from the trace; `None`
    /// lets the runtime key the workflow's stages by its workflow id.
    pub session: Option<u64>,
}

/// Bursty trace generator.
#[derive(Debug, Clone)]
pub struct TraceGen {
    /// Gamma shape for inter-arrival gaps; < 1 = bursty. CV = 1/sqrt(shape).
    pub burst_shape: f64,
}

impl Default for TraceGen {
    fn default() -> Self {
        // CV ≈ 1.8 like production LLM traces.
        TraceGen { burst_shape: 0.31 }
    }
}

impl TraceGen {
    /// A generator with a validated burst shape: non-finite or
    /// non-positive values are rejected at construction, naming the value
    /// — a NaN or zero shape would otherwise flow silently into the Gamma
    /// sampler and produce NaN inter-arrival gaps.
    pub fn new(burst_shape: f64) -> Result<TraceGen, String> {
        if !burst_shape.is_finite() || burst_shape <= 0.0 {
            return Err(format!(
                "burst_shape must be a positive finite number, got {burst_shape}"
            ));
        }
        Ok(TraceGen { burst_shape })
    }

    /// Generate `n` arrivals at `rate` tasks/second from `mix`.
    pub fn generate(
        &self,
        mix: &WorkloadMix,
        rate: f64,
        n: usize,
        rng: &mut Rng,
    ) -> Vec<ArrivalEvent> {
        assert!(rate > 0.0);
        assert!(
            self.burst_shape.is_finite() && self.burst_shape > 0.0,
            "invalid burst_shape {} (construct via TraceGen::new)",
            self.burst_shape
        );
        let mean_gap = 1.0 / rate;
        let gap_dist = Gamma::new(self.burst_shape, mean_gap / self.burst_shape);
        let weights: Vec<f64> = mix.entries.iter().map(|e| e.2).collect();
        let cat = crate::stats::dist::Categorical::new(&weights);

        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            t += gap_dist.sample(rng);
            let (app, ds, _) = mix.entries[cat.sample_index(rng)];
            out.push(ArrivalEvent {
                at: t,
                plan: WorkflowPlan::sample(app, ds, rng),
                session: None,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_rate_matches_target() {
        let gen = TraceGen::default();
        let mut rng = Rng::new(1);
        let n = 20_000;
        let evs = gen.generate(&WorkloadMix::colocated(), 8.0, n, &mut rng);
        let span = evs.last().unwrap().at;
        let rate = n as f64 / span;
        assert!((rate - 8.0).abs() / 8.0 < 0.1, "rate={rate}");
    }

    #[test]
    fn arrivals_are_bursty() {
        // CV of inter-arrival gaps should be >> 1 (Poisson would be 1).
        let gen = TraceGen::default();
        let mut rng = Rng::new(2);
        let evs = gen.generate(&WorkloadMix::single(App::Rg, "TQ"), 4.0, 20_000, &mut rng);
        let gaps: Vec<f64> = evs.windows(2).map(|w| w[1].at - w[0].at).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var =
            gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!(cv > 1.4, "cv={cv}");
    }

    #[test]
    fn arrival_times_monotone() {
        let gen = TraceGen::default();
        let mut rng = Rng::new(3);
        let evs = gen.generate(&WorkloadMix::colocated(), 2.0, 500, &mut rng);
        for w in evs.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
    }

    #[test]
    fn mix_respected() {
        let gen = TraceGen::default();
        let mut rng = Rng::new(4);
        let evs = gen.generate(&WorkloadMix::colocated(), 5.0, 6000, &mut rng);
        let qa = evs.iter().filter(|e| e.plan.app == App::Qa).count() as f64 / 6000.0;
        assert!((qa - 1.0 / 3.0).abs() < 0.05, "qa share {qa}");
    }

    #[test]
    fn burst_shape_validated_at_construction() {
        assert!((TraceGen::new(0.31).unwrap().burst_shape - 0.31).abs() < 1e-12);
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let err = TraceGen::new(bad).unwrap_err();
            assert!(err.contains("burst_shape"), "{err}");
            assert!(err.contains(&format!("{bad}")), "error names the value: {err}");
        }
    }

    #[test]
    #[should_panic(expected = "burst_shape")]
    fn generate_rejects_a_hand_built_invalid_shape() {
        // Construction bypass (struct literal) still cannot reach the
        // sampler: NaN gaps would silently corrupt every downstream time.
        let gen = TraceGen { burst_shape: f64::NAN };
        gen.generate(&WorkloadMix::colocated(), 1.0, 1, &mut Rng::new(1));
    }

    #[test]
    fn deterministic_per_seed() {
        let gen = TraceGen::default();
        let a = gen.generate(&WorkloadMix::colocated(), 5.0, 100, &mut Rng::new(7));
        let b = gen.generate(&WorkloadMix::colocated(), 5.0, 100, &mut Rng::new(7));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.plan.stages.len(), y.plan.stages.len());
        }
    }
}
