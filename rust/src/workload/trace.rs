//! First-class workload traces: record, replay and transform.
//!
//! The paper evaluates by replaying a production trace (Splitwise, §7.1)
//! proportionally scaled until the queuing ratio spans 0–90%. Until this
//! module, our workload layer could only *generate* arrivals — every sweep
//! arm regenerated its own, and cross-arm comparability rested on seed
//! discipline. A [`Trace`] is the explicit, serializable artifact instead:
//! one materialized arrival sequence that every consumer (sweep arms, both
//! drivers, benches) shares by construction, that any run can *record*
//! ([`crate::server::coordinator::Coordinator::trace_log`]) and replay
//! bit-identically, and that deterministic transforms ([`Trace::scale_rate`],
//! [`Trace::clip`], [`Trace::splice`], [`Trace::filter_app`]) turn into a
//! family of scenarios.
//!
//! The interchange format is JSONL — one [`TraceRecord`] per line, written
//! and parsed with the in-tree [`crate::util::json`] (floats round-trip
//! exactly: Rust's shortest-representation `Display` is re-parsed to the
//! identical bits). Producers are the [`TraceSource`] implementations:
//! [`GenSource`] (the existing [`TraceGen`], generate-then-materialize) and
//! [`FileSource`] (the loader for recorded files).

use std::path::{Path, PathBuf};

use crate::agents::apps::{App, PlannedStage, WorkflowPlan};
use crate::engine::cost_model::ModelClass;
use crate::stats::rng::Rng;
use crate::util::json::Json;
use crate::workload::{ArrivalEvent, TraceGen, WorkloadMix};
use crate::Time;

/// One stage of a recorded workflow: which agent ran and the token shape
/// its request had. See [`TraceRecord`] for the serialized form.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageRecord {
    /// Agent name (interned to the static agent table on load; unknown
    /// names from external traces are interned once per unique name).
    pub agent: &'static str,
    /// Prompt tokens of the stage's request.
    pub prompt_tokens: u32,
    /// Output tokens the stage generated.
    pub output_tokens: u32,
    /// Optional serving-group stamp: the model class the stage's request
    /// carried when the trace was recorded (`None` = unpinned/`Any`).
    /// Informational — replay re-derives classes from the active affinity
    /// config — but lets `kairos trace stats` and analyses see how the
    /// recorded run was routed.
    pub class: Option<ModelClass>,
}

/// One arriving user task of a recorded workload — the canonical JSONL
/// trace schema, one record per line.
///
/// Serialized fields:
///
/// | key       | type   | meaning                                          |
/// |-----------|--------|--------------------------------------------------|
/// | `at`      | number | arrival time in seconds from trace start (≥ 0)   |
/// | `app`     | string | application name as [`App::name`]: `QA`/`RG`/`CG`|
/// | `dataset` | string | dataset label the task was sampled from          |
/// | `stages`  | array  | resolved stage sequence, in execution order      |
/// | `session` | number | optional prefix-cache session key override;      |
/// |           |        | omitted = default (the task's workflow id)       |
///
/// Each entry of `stages` is an object:
///
/// | key      | type   | meaning                                            |
/// |----------|--------|----------------------------------------------------|
/// | `agent`  | string | agent name (e.g. `ResearchAgent`)                  |
/// | `prompt` | number | prompt tokens (non-negative integer)               |
/// | `output` | number | generated tokens (non-negative integer)            |
/// | `class`  | string | optional model-class stamp (e.g. `llama2-13b`);    |
/// |          |        | omitted when the request was unpinned (`Any`)      |
///
/// A sample line:
///
/// ```text
/// {"app":"RG","at":1.9330527,"dataset":"TQ","stages":[{"agent":"ResearchAgent","output":61,"prompt":733},{"agent":"WriterAgent","class":"llama3-8b","output":187,"prompt":490}]}
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// Arrival time, seconds from trace start.
    pub at: Time,
    /// The application the task instantiates.
    pub app: App,
    /// Dataset label the task was sampled from.
    pub dataset: &'static str,
    /// The resolved stage sequence (agents + token shapes).
    pub stages: Vec<StageRecord>,
    /// Optional prefix-cache session key override. `None` (the default,
    /// omitted on the wire) keys the task's stages by its workflow id;
    /// external traces set it to group tasks into longer-lived sessions.
    pub session: Option<u64>,
}

/// Known static names (agents + datasets) so loaded traces re-use the
/// compile-time strings instead of leaking one allocation per record.
const STATIC_NAMES: &[&str] = &[
    "EXT",
    "external",
    "Router",
    "MathAgent",
    "HumanitiesAgent",
    "ResearchAgent",
    "WriterAgent",
    "ProductManager",
    "Architect",
    "ProjectManager",
    "Engineer",
    "QAEngineer",
    "G+M",
    "M+W",
    "S+S",
    "TQ",
    "NCD",
    "NQ",
    "HE",
    "MBPP",
    "APPS",
];

/// Intern an arbitrary trace string to a `'static` lifetime: known names
/// resolve to the compile-time table; unknown names (external traces, or
/// agents submitted through the serving frontend) go through the shared
/// process-wide pool ([`crate::util::intern()`]), so a name also interned
/// by the [`crate::orchestrator::AgentRegistry`] is leaked only once.
/// Public so the coordinator's recording path can capture
/// `submit_external` agent names into [`StageRecord`]s.
pub fn intern_name(s: &str) -> &'static str {
    if let Some(&k) = STATIC_NAMES.iter().find(|&&k| k == s) {
        return k;
    }
    crate::util::intern(s)
}

impl TraceRecord {
    /// Record one submitted plan at its submission time (no class stamps;
    /// the coordinator's recording path stamps them from its affinity
    /// state).
    pub fn from_plan(plan: &WorkflowPlan, at: Time) -> TraceRecord {
        TraceRecord {
            at,
            app: plan.app,
            dataset: plan.dataset,
            stages: plan
                .stages
                .iter()
                .map(|s| StageRecord {
                    agent: s.agent,
                    prompt_tokens: s.prompt_tokens,
                    output_tokens: s.output_tokens,
                    class: None,
                })
                .collect(),
            session: None,
        }
    }

    /// The workflow plan this record resolves to on replay.
    pub fn plan(&self) -> WorkflowPlan {
        WorkflowPlan {
            app: self.app,
            dataset: self.dataset,
            stages: self
                .stages
                .iter()
                .map(|s| PlannedStage {
                    agent: s.agent,
                    prompt_tokens: s.prompt_tokens,
                    output_tokens: s.output_tokens,
                })
                .collect(),
        }
    }

    /// Serialize to one JSON object (one JSONL line, sans newline).
    pub fn to_json(&self) -> Json {
        let stages: Vec<Json> = self
            .stages
            .iter()
            .map(|s| {
                let mut pairs = vec![
                    ("agent", Json::from(s.agent)),
                    ("prompt", Json::from(s.prompt_tokens as usize)),
                    ("output", Json::from(s.output_tokens as usize)),
                ];
                if let Some(c) = s.class {
                    pairs.push(("class", Json::from(c.name())));
                }
                Json::obj(pairs)
            })
            .collect();
        let mut pairs = vec![
            ("at", Json::from(self.at)),
            ("app", Json::from(self.app.name())),
            ("dataset", Json::from(self.dataset)),
            ("stages", Json::Arr(stages)),
        ];
        if let Some(s) = self.session {
            pairs.push(("session", Json::from(s as usize)));
        }
        Json::obj(pairs)
    }

    /// Parse one record from its JSON object form.
    pub fn from_json(j: &Json) -> Result<TraceRecord, String> {
        fn str_field<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
            match j.get(key).and_then(Json::as_str) {
                Some(s) => Ok(s),
                None => Err(format!("missing or non-string {key:?}")),
            }
        }
        let at = match j.get("at").and_then(Json::as_f64) {
            Some(t) => t,
            None => return Err("missing or non-numeric \"at\"".to_string()),
        };
        if !at.is_finite() || at < 0.0 {
            return Err(format!("\"at\" must be a non-negative finite time, got {at}"));
        }
        let app = App::parse(str_field(j, "app")?)?;
        let dataset = str_field(j, "dataset")?;
        let raw_stages = match j.get("stages").and_then(Json::as_arr) {
            Some(a) => a,
            None => return Err("missing \"stages\" array".to_string()),
        };
        if raw_stages.is_empty() {
            return Err("\"stages\" must not be empty".to_string());
        }
        let mut stages = Vec::with_capacity(raw_stages.len());
        for (i, s) in raw_stages.iter().enumerate() {
            let agent = str_field(s, "agent").map_err(|e| format!("stage {i}: {e}"))?;
            let tokens = |key: &str| -> Result<u32, String> {
                let n = match s.get(key).and_then(Json::as_u64) {
                    Some(n) => n,
                    None => {
                        return Err(format!("stage {i}: missing or non-integer {key:?}"))
                    }
                };
                u32::try_from(n).map_err(|_| format!("stage {i}: {key:?} too large: {n}"))
            };
            let class = match s.get("class") {
                None => None,
                Some(Json::Str(name)) => {
                    Some(ModelClass::parse(name).map_err(|e| format!("stage {i}: {e}"))?)
                }
                Some(_) => {
                    return Err(format!("stage {i}: \"class\" must be a string"))
                }
            };
            stages.push(StageRecord {
                agent: intern_name(agent),
                prompt_tokens: tokens("prompt")?,
                output_tokens: tokens("output")?,
                class,
            });
        }
        let session = match j.get("session") {
            None => None,
            Some(s) => match s.as_u64() {
                Some(n) => Some(n),
                None => {
                    return Err("\"session\" must be a non-negative integer".to_string())
                }
            },
        };
        Ok(TraceRecord { at, app, dataset: intern_name(dataset), stages, session })
    }
}

/// A materialized workload trace: the ordered arrival records every
/// consumer shares. Construction is the only place randomness can enter
/// ([`GenSource`]); every method on `Trace` itself is deterministic.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Trace {
    pub records: Vec<TraceRecord>,
}

impl Trace {
    /// Wrap already-ordered records (e.g. a run's recorded `trace_log`).
    pub fn from_records(records: Vec<TraceRecord>) -> Trace {
        Trace { records }
    }

    /// Materialize generator output (no class stamps).
    pub fn from_arrivals(arrivals: &[ArrivalEvent]) -> Trace {
        Trace {
            records: arrivals
                .iter()
                .map(|a| {
                    let mut r = TraceRecord::from_plan(&a.plan, a.at);
                    r.session = a.session;
                    r
                })
                .collect(),
        }
    }

    /// The arrival sequence this trace replays to, in record order.
    pub fn arrivals(&self) -> Vec<ArrivalEvent> {
        self.records
            .iter()
            .map(|r| ArrivalEvent { at: r.at, plan: r.plan(), session: r.session })
            .collect()
    }

    /// Number of arrival records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether the trace holds no records.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Arrival time of the last record (0 for an empty trace).
    pub fn span(&self) -> Time {
        self.records.last().map_or(0.0, |r| r.at)
    }

    /// Mean arrival rate over the trace span (0 for degenerate traces).
    pub fn mean_rate(&self) -> f64 {
        let span = self.span();
        if span > 0.0 {
            self.records.len() as f64 / span
        } else {
            0.0
        }
    }

    /// Serialize to JSONL: one record per line, in order.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.records {
            out.push_str(&r.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Parse a JSONL document; blank lines are skipped, errors name the
    /// offending line. Arrival times must be non-decreasing — every
    /// consumer (span/rate stats, splice shifting, the drivers' warmup
    /// cutoff) assumes time order, so an out-of-order file is rejected
    /// here instead of corrupting results downstream.
    pub fn from_jsonl(text: &str) -> Result<Trace, String> {
        let mut records: Vec<TraceRecord> = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
            let rec =
                TraceRecord::from_json(&j).map_err(|e| format!("line {}: {e}", i + 1))?;
            if let Some(prev) = records.last() {
                if rec.at < prev.at {
                    return Err(format!(
                        "line {}: arrival time {} goes backwards (previous {})",
                        i + 1,
                        rec.at,
                        prev.at
                    ));
                }
            }
            records.push(rec);
        }
        Ok(Trace { records })
    }

    /// Write the JSONL form to `path`.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        // kairos-lint: allow(no-env-fs, trace persistence is this type's contract; callers pass explicit paths)
        std::fs::write(path, self.to_jsonl())
            .map_err(|e| format!("cannot write trace {}: {e}", path.display()))
    }

    /// Load a JSONL trace from `path`.
    pub fn load(path: &Path) -> Result<Trace, String> {
        // kairos-lint: allow(no-env-fs, trace persistence is this type's contract; callers pass explicit paths)
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read trace {}: {e}", path.display()))?;
        Self::from_jsonl(&text)
            .map_err(|e| format!("trace {}: {e}", path.display()))
    }

    /// Assign session keys round-robin over `n` long-running sessions
    /// (record `i` → session `i % n`) — a session-heavy derivative of any
    /// trace for prefix-cache experiments: consecutive arrivals of the
    /// same session share a growing context prefix. `n = 0` clears the
    /// keys back to the per-workflow default. Order-preserving.
    pub fn sessionize(&self, n: u64) -> Trace {
        let mut out = self.clone();
        for (i, r) in out.records.iter_mut().enumerate() {
            r.session = if n == 0 { None } else { Some(i as u64 % n) };
        }
        out
    }

    /// Scale the arrival rate by `factor` (> 1 = denser load): every
    /// arrival time is divided by `factor`, preserving order and relative
    /// burst structure — the paper's proportional load scaling.
    pub fn scale_rate(&self, factor: f64) -> Result<Trace, String> {
        if !factor.is_finite() || factor <= 0.0 {
            return Err(format!(
                "scale factor must be a positive finite number, got {factor}"
            ));
        }
        let mut out = self.clone();
        for r in &mut out.records {
            r.at /= factor;
        }
        Ok(out)
    }

    /// Keep only arrivals inside `[start, end)`, rebased so the window
    /// starts at time 0. Order-preserving.
    pub fn clip(&self, start: Time, end: Time) -> Result<Trace, String> {
        if !start.is_finite() || start < 0.0 || end.is_nan() || end < start {
            return Err(format!("bad clip window [{start}, {end})"));
        }
        let records = self
            .records
            .iter()
            .filter(|r| r.at >= start && r.at < end)
            .map(|r| {
                let mut r = r.clone();
                r.at -= start;
                r
            })
            .collect();
        Ok(Trace { records })
    }

    /// Append `other` after this trace: its arrivals are shifted by this
    /// trace's span so the combined timeline stays monotone when both
    /// inputs are. Order-preserving on both sides.
    pub fn splice(&self, other: &Trace) -> Trace {
        let shift = self.span();
        let mut records = self.records.clone();
        records.extend(other.records.iter().map(|r| {
            let mut r = r.clone();
            r.at += shift;
            r
        }));
        Trace { records }
    }

    /// Keep only arrivals of one application (times untouched, so the
    /// app's own burst structure is preserved). Order-preserving.
    pub fn filter_app(&self, app: App) -> Trace {
        Trace {
            records: self.records.iter().filter(|r| r.app == app).cloned().collect(),
        }
    }
}

/// A producer of materialized traces. The seam every workload consumer
/// goes through: sweeps materialize ONE trace from their source and run
/// every arm off it, so baselines are apples-to-apples by construction
/// instead of by seed discipline.
pub trait TraceSource {
    /// Materialize the full trace.
    fn materialize(&self) -> Result<Trace, String>;
    /// Human-readable provenance, for run headers.
    fn describe(&self) -> String;
}

/// Generate-then-materialize over the existing [`TraceGen`].
#[derive(Debug, Clone)]
pub struct GenSource {
    pub gen: TraceGen,
    pub mix: WorkloadMix,
    /// Target mean arrival rate (tasks/second); must be positive.
    pub rate: f64,
    /// Number of tasks to generate; must be positive.
    pub n: usize,
    pub seed: u64,
}

impl TraceSource for GenSource {
    fn materialize(&self) -> Result<Trace, String> {
        if !self.rate.is_finite() || self.rate <= 0.0 {
            return Err(format!("rate must be a positive finite number, got {}", self.rate));
        }
        if self.n == 0 {
            return Err("cannot materialize an empty trace (n = 0)".to_string());
        }
        let arrivals =
            self.gen.generate(&self.mix, self.rate, self.n, &mut Rng::new(self.seed));
        Ok(Trace::from_arrivals(&arrivals))
    }

    fn describe(&self) -> String {
        format!(
            "generated: {} tasks at {} req/s, burst_shape {}, seed {}",
            self.n, self.rate, self.gen.burst_shape, self.seed
        )
    }
}

/// Load a recorded JSONL trace from disk.
#[derive(Debug, Clone)]
pub struct FileSource {
    pub path: PathBuf,
}

impl FileSource {
    /// A source reading the JSONL trace at `path` on materialize.
    pub fn new(path: impl Into<PathBuf>) -> FileSource {
        FileSource { path: path.into() }
    }
}

impl TraceSource for FileSource {
    fn materialize(&self) -> Result<Trace, String> {
        Trace::load(&self.path)
    }

    fn describe(&self) -> String {
        format!("recorded: {}", self.path.display())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    fn sample_trace(n: usize, rate: f64, seed: u64) -> Trace {
        GenSource {
            gen: TraceGen::default(),
            mix: WorkloadMix::colocated(),
            rate,
            n,
            seed,
        }
        .materialize()
        .unwrap()
    }

    #[test]
    fn jsonl_round_trip_is_identity() {
        let t = sample_trace(50, 4.0, 7);
        let back = Trace::from_jsonl(&t.to_jsonl()).unwrap();
        assert_eq!(t, back, "Trace -> JSONL -> Trace must be identity");
        // Includes exact f64 arrival times, not approximate ones.
        for (a, b) in t.records.iter().zip(&back.records) {
            assert!(a.at.to_bits() == b.at.to_bits(), "bit-exact times");
        }
    }

    #[test]
    fn sessionize_assigns_round_robin_keys() {
        let t = sample_trace(10, 4.0, 3);
        let s = t.sessionize(3);
        for (i, r) in s.records.iter().enumerate() {
            assert_eq!(r.session, Some(i as u64 % 3));
        }
        // The keys survive the JSONL round trip and flow into arrivals.
        let back = Trace::from_jsonl(&s.to_jsonl()).unwrap();
        assert_eq!(back, s);
        assert_eq!(s.arrivals()[4].session, Some(1));
        // n = 0 clears back to the per-workflow default.
        assert!(s.sessionize(0).records.iter().all(|r| r.session.is_none()));
    }

    #[test]
    fn jsonl_round_trip_is_identity_property() {
        forall(
            "trace-jsonl-roundtrip",
            25,
            101,
            |rng| {
                let n = rng.range(1, 40);
                let rate = 0.5 + rng.f64() * 10.0;
                sample_trace(n, rate, rng.next_u64())
            },
            |t| {
                let back = Trace::from_jsonl(&t.to_jsonl())
                    .map_err(|e| format!("parse failed: {e}"))?;
                if back != *t {
                    return Err("round trip not identity".to_string());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn transforms_are_deterministic_and_order_preserving() {
        forall(
            "trace-transforms",
            20,
            102,
            |rng| {
                let a = sample_trace(rng.range(2, 30), 3.0, rng.next_u64());
                let b = sample_trace(rng.range(1, 20), 6.0, rng.next_u64());
                (a, b)
            },
            |(a, b)| {
                let scaled = a.scale_rate(2.0).unwrap();
                if scaled != a.scale_rate(2.0).unwrap() {
                    return Err("scale_rate not deterministic".to_string());
                }
                if scaled.len() != a.len() {
                    return Err("scale_rate changed record count".to_string());
                }
                let clipped = a.clip(0.5, a.span()).unwrap();
                if clipped != a.clip(0.5, a.span()).unwrap() {
                    return Err("clip not deterministic".to_string());
                }
                let spliced = a.splice(b);
                if spliced != a.splice(b) {
                    return Err("splice not deterministic".to_string());
                }
                if spliced.len() != a.len() + b.len() {
                    return Err("splice lost records".to_string());
                }
                // Order preservation: all three outputs stay monotone in
                // time (the inputs are).
                for t in [&scaled, &clipped, &spliced] {
                    for w in t.records.windows(2) {
                        if w[1].at < w[0].at {
                            return Err("transform broke time order".to_string());
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn scale_rate_moves_the_mean_rate() {
        let t = sample_trace(400, 4.0, 11);
        let denser = t.scale_rate(2.0).unwrap();
        assert_eq!(denser.len(), t.len());
        let ratio = denser.mean_rate() / t.mean_rate();
        assert!((ratio - 2.0).abs() < 1e-9, "ratio={ratio}");
        assert!(t.scale_rate(0.0).is_err());
        assert!(t.scale_rate(f64::NAN).is_err());
        assert!(t.scale_rate(f64::INFINITY).is_err());
    }

    #[test]
    fn clip_rebases_the_window() {
        let t = sample_trace(200, 5.0, 12);
        let mid = t.span() / 2.0;
        let tail = t.clip(mid, f64::MAX).unwrap();
        assert!(!tail.is_empty() && tail.len() < t.len());
        assert!(tail.records[0].at < t.records[0].at + mid, "rebased to ~0");
        for r in &tail.records {
            assert!(r.at >= 0.0);
        }
        assert!(t.clip(3.0, 1.0).is_err(), "inverted window rejected");
        assert!(t.clip(-1.0, 1.0).is_err());
        assert!(t.clip(f64::NAN, 1.0).is_err());
    }

    #[test]
    fn splice_concatenates_on_the_timeline() {
        let a = sample_trace(40, 4.0, 13);
        let b = sample_trace(30, 4.0, 14);
        let s = a.splice(&b);
        assert_eq!(s.len(), 70);
        assert_eq!(&s.records[..40], &a.records[..]);
        let shift = a.span();
        for (orig, spliced) in b.records.iter().zip(&s.records[40..]) {
            assert_eq!(spliced.at, orig.at + shift);
            assert_eq!(spliced.stages, orig.stages);
        }
    }

    #[test]
    fn filter_app_keeps_only_that_app() {
        let t = sample_trace(300, 5.0, 15);
        let qa = t.filter_app(App::Qa);
        assert!(!qa.is_empty() && qa.len() < t.len());
        assert!(qa.records.iter().all(|r| r.app == App::Qa));
        let total = App::all().iter().map(|&a| t.filter_app(a).len()).sum::<usize>();
        assert_eq!(total, t.len(), "apps partition the trace");
    }

    #[test]
    fn arrivals_replay_the_recorded_plans() {
        let src = GenSource {
            gen: TraceGen::default(),
            mix: WorkloadMix::colocated(),
            rate: 4.0,
            n: 60,
            seed: 16,
        };
        let original = src
            .gen
            .generate(&src.mix, src.rate, src.n, &mut Rng::new(src.seed));
        let replayed = src.materialize().unwrap().arrivals();
        assert_eq!(original, replayed, "materialize→arrivals is lossless");
    }

    #[test]
    fn class_stamp_survives_the_round_trip() {
        use crate::engine::cost_model::ModelKind;
        let mut t = sample_trace(5, 2.0, 17);
        t.records[0].stages[0].class =
            Some(ModelClass::Model(ModelKind::Llama2_13B));
        t.records[1].stages[0].class = Some(ModelClass::Any);
        let back = Trace::from_jsonl(&t.to_jsonl()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn session_key_survives_the_round_trip_and_stays_omitted_when_unset() {
        let mut t = sample_trace(4, 2.0, 21);
        t.records[0].session = Some(9001);
        t.records[2].session = Some(0);
        let jsonl = t.to_jsonl();
        // Unset records carry no "session" key at all (bit-identity with
        // pre-session traces).
        let lines: Vec<&str> = jsonl.lines().collect();
        assert!(lines[0].contains("\"session\":9001"));
        assert!(!lines[1].contains("session"));
        assert!(lines[2].contains("\"session\":0"));
        let back = Trace::from_jsonl(&jsonl).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.arrivals()[0].session, Some(9001));
        assert_eq!(back.arrivals()[1].session, None);
        // Non-integer session keys are rejected, naming the field.
        let bad = "{\"at\":0,\"app\":\"RG\",\"dataset\":\"TQ\",\"session\":-3,\
                   \"stages\":[{\"agent\":\"A\",\"prompt\":1,\"output\":1}]}";
        assert!(Trace::from_jsonl(bad).unwrap_err().contains("session"));
    }

    #[test]
    fn loader_rejects_garbage_naming_the_line() {
        let err = Trace::from_jsonl("{\"app\":\"RG\"}\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let good = sample_trace(2, 2.0, 18).to_jsonl();
        let err = Trace::from_jsonl(&format!("{good}not json\n")).unwrap_err();
        assert!(err.contains("line 3"), "{err}");
        // Bad field values name the problem.
        let bad_at = "{\"at\":-1,\"app\":\"RG\",\"dataset\":\"TQ\",\
                      \"stages\":[{\"agent\":\"A\",\"prompt\":1,\"output\":1}]}";
        assert!(Trace::from_jsonl(bad_at).unwrap_err().contains("at"));
        let bad_app = "{\"at\":0,\"app\":\"ZZ\",\"dataset\":\"TQ\",\
                       \"stages\":[{\"agent\":\"A\",\"prompt\":1,\"output\":1}]}";
        assert!(Trace::from_jsonl(bad_app).unwrap_err().contains("ZZ"));
        let bad_tok = "{\"at\":0,\"app\":\"RG\",\"dataset\":\"TQ\",\
                       \"stages\":[{\"agent\":\"A\",\"prompt\":1.5,\"output\":1}]}";
        assert!(Trace::from_jsonl(bad_tok).unwrap_err().contains("prompt"));
        let no_stages =
            "{\"at\":0,\"app\":\"RG\",\"dataset\":\"TQ\",\"stages\":[]}";
        assert!(Trace::from_jsonl(no_stages).unwrap_err().contains("stages"));
    }

    #[test]
    fn loader_rejects_out_of_order_arrival_times() {
        // Every consumer (span, splice shifting, the drivers' warmup
        // cutoff) assumes time order: a hand-edited file that goes
        // backwards must fail at load, naming the line.
        let line = |at: f64| {
            format!(
                "{{\"at\":{at},\"app\":\"RG\",\"dataset\":\"TQ\",\
                 \"stages\":[{{\"agent\":\"A\",\"prompt\":1,\"output\":1}}]}}"
            )
        };
        let doc = format!("{}\n{}\n{}\n", line(1.0), line(10.0), line(2.0));
        let err = Trace::from_jsonl(&doc).unwrap_err();
        assert!(err.contains("line 3"), "{err}");
        assert!(err.contains("backwards"), "{err}");
        // Equal timestamps (simultaneous arrivals) are fine.
        let ok = format!("{}\n{}\n", line(1.0), line(1.0));
        assert_eq!(Trace::from_jsonl(&ok).unwrap().len(), 2);
    }

    #[test]
    fn unknown_names_intern_to_stable_statics() {
        let line = "{\"at\":0.5,\"app\":\"RG\",\"dataset\":\"external-ds\",\
                    \"stages\":[{\"agent\":\"CustomAgent\",\"prompt\":8,\"output\":4}]}";
        let a = Trace::from_jsonl(line).unwrap();
        let b = Trace::from_jsonl(line).unwrap();
        // Same leaked pointer on repeated loads (no unbounded leaking).
        assert!(std::ptr::eq(a.records[0].dataset, b.records[0].dataset));
        assert!(std::ptr::eq(a.records[0].stages[0].agent, b.records[0].stages[0].agent));
        // Known names resolve through the compile-time table.
        let t = sample_trace(3, 2.0, 19);
        let back = Trace::from_jsonl(&t.to_jsonl()).unwrap();
        assert!(STATIC_NAMES.contains(&back.records[0].dataset));
        assert_eq!(t.records[0].dataset, back.records[0].dataset);
    }

    #[test]
    fn gen_source_validates_inputs() {
        let mut src = GenSource {
            gen: TraceGen::default(),
            mix: WorkloadMix::colocated(),
            rate: 0.0,
            n: 10,
            seed: 1,
        };
        assert!(src.materialize().unwrap_err().contains("rate"));
        src.rate = 2.0;
        src.n = 0;
        assert!(src.materialize().is_err());
        src.n = 10;
        assert!(src.materialize().is_ok());
        assert!(src.describe().contains("generated"));
    }

    #[test]
    fn file_source_round_trips_through_disk() {
        let t = sample_trace(25, 3.0, 20);
        let path = std::env::temp_dir().join("kairos_trace_test_roundtrip.jsonl");
        t.save(&path).unwrap();
        let back = FileSource::new(&path).materialize().unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(t, back);
        assert!(FileSource::new("/nonexistent/kairos.jsonl")
            .materialize()
            .unwrap_err()
            .contains("nonexistent"));
    }
}
