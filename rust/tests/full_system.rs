//! Integration tests over the composed system: workload → queue →
//! scheduler → dispatcher → engines → orchestrator → metrics.

use kairos::agents::apps::App;
use kairos::engine::cost_model::ModelKind;
use kairos::orchestrator::affinity::AffinitySpec;
use kairos::orchestrator::router::{RoutePolicy, RouteReason};
use kairos::server::coordinator::FleetSpec;
use kairos::server::sim::{
    make_dispatcher, make_policy, run_fleet, run_system, FleetConfig, SimConfig, SimServer,
};
use kairos::stats::rng::Rng;
use kairos::workload::{ArrivalEvent, TraceGen, WorkloadMix};

fn trace(mix: &WorkloadMix, rate: f64, n: usize, seed: u64) -> Vec<ArrivalEvent> {
    TraceGen::default().generate(mix, rate, n, &mut Rng::new(seed))
}

#[test]
fn every_policy_pair_completes_the_trace() {
    let cfg = SimConfig { n_instances: 2, ..Default::default() };
    for sched in ["parrot", "ayo", "kairos", "oracle"] {
        for disp in ["rr", "kairos", "oracle", "least"] {
            let res = run_system(cfg, sched, disp, trace(&WorkloadMix::colocated(), 4.0, 120, 1));
            assert!(
                res.summary.n_workflows > 0,
                "{sched}/{disp}: no workflows finished"
            );
            assert_eq!(res.dropped_requests, 0, "{sched}/{disp}: dropped");
            assert!(res.summary.avg_token_latency.is_finite());
        }
    }
}

#[test]
fn request_conservation_across_stack() {
    // Total stage records == total stages of completed workflows.
    let cfg = SimConfig { n_instances: 2, ..Default::default() };
    let arrivals = trace(&WorkloadMix::single(App::Rg, "TQ"), 3.0, 100, 2);
    let res = run_system(cfg, "kairos", "kairos", arrivals);
    // RG is always exactly 2 stages.
    assert_eq!(res.metrics.requests.len(), res.metrics.workflows.len() * 2);
}

#[test]
fn workflow_latency_accounting_consistent() {
    let cfg = SimConfig { n_instances: 2, ..Default::default() };
    let res = run_system(cfg, "parrot", "rr", trace(&WorkloadMix::colocated(), 4.0, 150, 3));
    for w in &res.metrics.workflows {
        assert!(w.finished_at > w.app_start);
        assert!(w.queue_time >= 0.0);
        assert!(w.queue_time <= w.e2e() + 1e-9, "queue time within e2e");
        assert!(w.output_tokens > 0);
    }
    for r in &res.metrics.requests {
        assert!(r.dispatched_at >= r.stage_arrival - 1e-9);
        assert!(r.finished_at > r.dispatched_at);
    }
}

#[test]
fn thirteen_b_slower_than_8b_at_same_load() {
    let arrivals = trace(&WorkloadMix::colocated(), 2.0, 150, 4);
    let cfg8 = SimConfig { n_instances: 2, ..Default::default() };
    let cfg13 = SimConfig { n_instances: 2, model: ModelKind::Llama2_13B, ..Default::default() };
    let r8 = run_system(cfg8, "parrot", "rr", arrivals.clone());
    let r13 = run_system(cfg13, "parrot", "rr", arrivals);
    assert!(
        r13.summary.avg_token_latency > r8.summary.avg_token_latency,
        "13B {} !> 8B {}",
        r13.summary.avg_token_latency,
        r8.summary.avg_token_latency
    );
}

#[test]
fn more_instances_reduce_latency_under_load() {
    let mk = |n: usize, seed: u64| {
        let cfg = SimConfig { n_instances: n, ..Default::default() };
        run_system(cfg, "parrot", "rr", trace(&WorkloadMix::colocated(), 6.0, 300, seed))
            .summary
            .avg_token_latency
    };
    let two = mk(2, 5);
    let eight = mk(8, 5);
    assert!(eight < two, "8 inst {eight} !< 2 inst {two}");
}

#[test]
fn orchestrator_reconstructs_qa_branch_online() {
    // Drive the server manually to inspect the orchestrator afterwards.
    let cfg = SimConfig { n_instances: 2, ..Default::default() };
    let policy = make_policy("kairos");
    let disp = make_dispatcher("kairos", &cfg);
    let server = SimServer::new(cfg, policy, disp);
    let arrivals = trace(&WorkloadMix::single(App::Qa, "G+M"), 3.0, 150, 6);
    let res = server.run(arrivals);
    // Both experts observed; router handled every workflow's first stage.
    let n_router = res
        .metrics
        .requests
        .iter()
        .filter(|r| r.agent.0 == 0) // Router interned first
        .count();
    assert_eq!(n_router, res.metrics.workflows.len());
}

#[test]
fn sharded_mixed_fleet_beats_unsharded_on_queuing_delay() {
    // Three healthy 8B instances plus one 13B co-tenant whose denser KV
    // makes it an order of magnitude smaller in tokens and ~1.7x slower.
    // Unsharded, the load-blind baseline dispatcher sprays every 4th
    // request onto the slow instance, whose engine queue balloons.
    // Sharded, every agent is pinned to the 8B group — the 13B co-tenant
    // simply never sees this workload — and mean queuing delay drops.
    let fleet = FleetSpec::parse("3*llama3-8b@0.12,llama2-13b@0.12").unwrap();
    let arrivals = trace(&WorkloadMix::colocated(), 1.5, 250, 9);
    let base = run_fleet(FleetConfig::from(fleet.clone()), "kairos", "rr", arrivals.clone());
    let sharded = {
        let mut cfg = FleetConfig::from(fleet);
        cfg.affinity = Some(AffinitySpec::parse("*=llama3-8b").unwrap());
        run_fleet(cfg, "kairos", "rr", arrivals)
    };
    // Acceptance: zero model-incompatible dispatches under sharding …
    assert_eq!(sharded.cross_model_dispatches(), 0, "model-incompatible dispatch");
    assert!(
        sharded.dispatch_log.iter().all(|&(_, j)| j != 3),
        "pinned workload reached the 13B co-tenant"
    );
    assert_eq!(sharded.dropped_requests, 0);
    assert!(!sharded.metrics.requests.is_empty());
    // … and lower mean queuing delay than the unsharded baseline on the
    // same trace.
    let (bq, sq) = (base.mean_queue_delay(), sharded.mean_queue_delay());
    assert!(sq < bq, "sharded mean queue delay {sq:.3}s !< unsharded {bq:.3}s");
}

#[test]
fn learned_routing_beats_static_pins_on_skewed_trace() {
    // The wrong static guess: EVERY agent pinned to the slower, KV-denser
    // 13B family while two 8B instances idle. Learned routing must sample
    // both families (deterministic exploration), measure that the 8B
    // group serves this workload faster, and migrate traffic — beating
    // the static-pin baseline's mean request E2E latency on the same
    // skewed mixed-model trace.
    let fleet = FleetSpec::parse("2*llama3-8b@0.12,2*llama2-13b@0.12").unwrap();
    let aff = AffinitySpec::parse("*=llama2-13b").unwrap();
    let arrivals = trace(&WorkloadMix::colocated(), 3.0, 300, 11);

    let mut pinned_cfg = FleetConfig::from(fleet.clone());
    pinned_cfg.affinity = Some(aff.clone());
    pinned_cfg.route = Some(RoutePolicy::Pinned);
    let pinned = run_fleet(pinned_cfg, "kairos", "kairos", arrivals.clone());

    let mut learned_cfg = FleetConfig::from(fleet);
    learned_cfg.affinity = Some(aff);
    learned_cfg.route =
        Some(RoutePolicy::Learned { explore_rate: 0.25, min_samples: 8 });
    let learned = run_fleet(learned_cfg, "kairos", "kairos", arrivals);

    // The learned run re-pinned hard, so the invariant still holds …
    assert_eq!(learned.cross_model_dispatches(), 0);
    // … the pinned baseline never touched the 8B half of the fleet …
    assert!(pinned.dispatch_log.iter().all(|&(_, j)| j >= 2));
    // … while learning moved real traffic onto it.
    let learned_to_8b =
        learned.dispatch_log.iter().filter(|&&(_, j)| j < 2).count();
    assert!(
        learned_to_8b > learned.dispatch_log.len() / 4,
        "only {learned_to_8b} of {} dispatches reached the 8B group",
        learned.dispatch_log.len()
    );
    assert!(
        learned.route_log.iter().any(|d| d.reason == RouteReason::LearnedBest),
        "profiles never converged to a learned stamp"
    );
    let (pe, le) = (pinned.mean_request_e2e(), learned.mean_request_e2e());
    assert!(
        le < pe,
        "learned mean E2E {le:.3}s !< static-pin baseline {pe:.3}s"
    );
}

#[test]
fn learned_any_balancing_is_work_conserving() {
    // Unpinned (Any) agents are balanced into per-group routed shards by
    // live pressure. Their dispatch constraint stays Any, so no request
    // can starve behind a pinned head and nothing drops.
    let fleet = FleetSpec::parse("3*llama3-8b@0.12,llama2-13b@0.12").unwrap();
    let aff = AffinitySpec::parse("Engineer=llama2-13b,QAEngineer=llama2-13b").unwrap();
    let arrivals = trace(&WorkloadMix::colocated(), 2.0, 200, 12);
    let mut cfg = FleetConfig::from(fleet);
    cfg.affinity = Some(aff);
    // No exploration, unreachable min_samples: pure pressure balancing of
    // the Any class plus hard pins as fallback.
    cfg.route = Some(RoutePolicy::Learned { explore_rate: 0.0, min_samples: 1_000_000 });
    let res = run_fleet(cfg, "kairos", "rr", arrivals);
    assert_eq!(res.dropped_requests, 0);
    assert_eq!(res.cross_model_dispatches(), 0);
    assert!(!res.metrics.requests.is_empty());
    assert_eq!(
        res.route_log.len(),
        res.dispatch_log.len(),
        "every routed request dispatched"
    );
    // Balancing actually engaged: Any requests were assigned groups.
    assert!(res
        .route_log
        .iter()
        .any(|d| d.reason == RouteReason::LeastPressured && d.group.is_some()));
    // And the pinned agents stayed on their fallback pins.
    assert!(res.route_log.iter().any(|d| d.reason == RouteReason::FallbackPin));
}

#[test]
fn kairos_tail_latency_improvement_under_overload() {
    // P99 improvement is the paper's strongest co-location claim.
    let cfg = SimConfig::default();
    let parrot = run_system(cfg, "parrot", "rr", trace(&WorkloadMix::colocated(), 6.0, 800, 7));
    let kairos =
        run_system(cfg, "kairos", "kairos", trace(&WorkloadMix::colocated(), 6.0, 800, 7));
    assert!(
        kairos.summary.p99_token_latency < parrot.summary.p99_token_latency,
        "kairos p99 {} !< parrot p99 {}",
        kairos.summary.p99_token_latency,
        parrot.summary.p99_token_latency
    );
}
