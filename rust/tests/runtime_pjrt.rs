//! Integration: the rust PJRT runtime reproduces the python reference
//! generation token-for-token from the AOT artifacts.
//!
//! Requires `make artifacts` to have run; tests skip (with a notice) if the
//! artifacts are missing so `cargo test` stays runnable pre-build.

use std::path::{Path, PathBuf};

use kairos::runtime::TinyModel;
use kairos::util::json::Json;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts(name: &str) -> bool {
    artifacts_dir().join(format!("{name}_manifest.json")).exists()
}

fn golden(name: &str) -> Json {
    let text =
        std::fs::read_to_string(artifacts_dir().join(format!("{name}_golden.json"))).unwrap();
    Json::parse(&text).unwrap()
}

#[test]
fn micro_model_matches_python_golden() {
    if !have_artifacts("micro") {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let model = TinyModel::load(&artifacts_dir(), "micro").unwrap();
    let g = golden("micro");
    let prompts: Vec<Vec<i32>> = g
        .get("prompts")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.as_arr().unwrap().iter().map(|t| t.as_f64().unwrap() as i32).collect())
        .collect();
    let steps = g.get("steps").unwrap().as_usize().unwrap();
    let want: Vec<Vec<i32>> = g
        .get("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.as_arr().unwrap().iter().map(|t| t.as_f64().unwrap() as i32).collect())
        .collect();

    let got = model.generate(&prompts, steps).unwrap();
    assert_eq!(got, want, "rust PJRT generation diverged from python golden");
}

#[test]
fn tiny_model_matches_python_golden() {
    if !have_artifacts("tiny") {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let model = TinyModel::load(&artifacts_dir(), "tiny").unwrap();
    let g = golden("tiny");
    let prompts: Vec<Vec<i32>> = g
        .get("prompts")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.as_arr().unwrap().iter().map(|t| t.as_f64().unwrap() as i32).collect())
        .collect();
    let steps = g.get("steps").unwrap().as_usize().unwrap();
    let want: Vec<Vec<i32>> = g
        .get("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.as_arr().unwrap().iter().map(|t| t.as_f64().unwrap() as i32).collect())
        .collect();

    let got = model.generate(&prompts, steps).unwrap();
    assert_eq!(got, want);
}

#[test]
fn generation_is_deterministic() {
    if !have_artifacts("micro") {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let model = TinyModel::load(&artifacts_dir(), "micro").unwrap();
    let prompts = vec![vec![1, 2, 3], vec![4, 5]];
    let a = model.generate(&prompts, 4).unwrap();
    let b = model.generate(&prompts, 4).unwrap();
    assert_eq!(a, b);
}

#[test]
fn rejects_bad_shapes() {
    if !have_artifacts("micro") {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let model = TinyModel::load(&artifacts_dir(), "micro").unwrap();
    let m = &model.manifest;
    // Wrong token count for prefill.
    assert!(model.prefill(&[0; 3], &vec![1; m.batch], &model.empty_kv()).is_err());
    // Wrong kv size for decode.
    assert!(model.decode(&vec![0; m.batch], &vec![1; m.batch], &[0.0; 7]).is_err());
}
