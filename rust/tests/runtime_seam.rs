//! The runtime seam contract: the discrete-event driver (`server::sim`)
//! and a wall-clock-style polling driver (a mock-backed stand-in for
//! `server::real`, advancing a [`ManualClock`] instead of blocking on real
//! compute) drive the SAME [`Coordinator`] — so the same workload trace
//! must produce the SAME dispatch decisions through either driver.
//!
//! Plus: heterogeneous-fleet coverage — per-instance KV budgets flow
//! through `InstanceStatus` into the dispatchers from both drivers — and
//! sharded-fleet coverage: with agents pinned to model families, the
//! per-group dispatch logs must match across drivers and no request may
//! ever land on a model-incompatible instance.

use kairos::engine::core::StepOutcome;
use kairos::engine::cost_model::{ModelClass, ModelKind};
use kairos::orchestrator::affinity::AffinitySpec;
use kairos::orchestrator::router::{RouteDecision, RoutePolicy, RouteReason};
use kairos::server::autoscale::{parse_per_group, AutoscaleConfig, Autoscaler};
use kairos::server::coordinator::{
    Clock, Coordinator, FleetSpec, GroupDispatch, LogConfig, ManualClock,
    ScaleEventKind,
};
use kairos::server::pressure::PressureTrace;
use kairos::server::sim::{
    make_dispatcher_for_fleet, make_dispatcher_routed, make_dispatcher_tuned,
    make_policy, run_fleet, CacheTuning, FleetConfig, SimResult, SimServer,
};
use kairos::stats::rng::Rng;
use kairos::workload::{ArrivalEvent, Trace, TraceGen, TraceRecord, WorkloadMix};

fn trace(rate: f64, n: usize, seed: u64) -> Vec<ArrivalEvent> {
    TraceGen::default().generate(&WorkloadMix::colocated(), rate, n, &mut Rng::new(seed))
}

/// A burst (overload) followed by a calm tail — the shape that makes an
/// autoscaler grow and then drain back down.
fn burst_then_calm(seed: u64) -> Vec<ArrivalEvent> {
    let gen = TraceGen::default();
    let mut rng = Rng::new(seed);
    let mut arrivals = gen.generate(&WorkloadMix::colocated(), 14.0, 260, &mut rng);
    let burst_end = arrivals.last().map(|a| a.at).unwrap_or(0.0);
    for mut a in gen.generate(&WorkloadMix::colocated(), 0.8, 60, &mut rng) {
        a.at += burst_end;
        arrivals.push(a);
    }
    arrivals
}

/// Outcome of one driver run, reduced to the seam contract. Scale events
/// are compared by (kind, instance, dispatch-log position): both drivers
/// must reshape the fleet at the same points of the dispatch stream. The
/// group log carries each dispatch's serving-group context, so equality
/// here IS per-group dispatch-log equality.
#[derive(Debug, PartialEq)]
struct DriverTrace {
    dispatch_log: Vec<(u64, usize)>,
    group_log: Vec<GroupDispatch>,
    route_log: Vec<RouteDecision>,
    scale_log: Vec<(ScaleEventKind, usize, usize)>,
    trace_log: Vec<TraceRecord>,
    dropped: u64,
    workflows_completed: usize,
    requests_completed: usize,
}

/// Drive the trace through the discrete-event driver.
fn drive_sim(
    fleet: &FleetSpec,
    scheduler: &str,
    dispatcher: &str,
    arrivals: Vec<ArrivalEvent>,
) -> DriverTrace {
    drive_sim_elastic(fleet, scheduler, dispatcher, arrivals, None, None, None, None)
}

#[allow(clippy::too_many_arguments)]
fn drive_sim_elastic(
    fleet: &FleetSpec,
    scheduler: &str,
    dispatcher: &str,
    arrivals: Vec<ArrivalEvent>,
    autoscale: Option<AutoscaleConfig>,
    pressure: Option<PressureTrace>,
    affinity: Option<AffinitySpec>,
    route: Option<RoutePolicy>,
) -> DriverTrace {
    let mut cfg = FleetConfig::from(fleet.clone());
    cfg.autoscale = autoscale;
    cfg.pressure = pressure;
    cfg.affinity = affinity;
    cfg.route = route;
    driver_trace_of(run_fleet(cfg, scheduler, dispatcher, arrivals))
}

/// Reduce a finished sim run to the seam contract.
fn driver_trace_of(res: SimResult) -> DriverTrace {
    DriverTrace {
        dispatch_log: res.dispatch_log,
        group_log: res.group_log,
        route_log: res.route_log,
        scale_log: res
            .scale_log
            .iter()
            .map(|e| (e.kind, e.instance, e.dispatch_seq))
            .collect(),
        trace_log: res.trace_log,
        dropped: res.dropped_requests,
        workflows_completed: res.metrics.workflows.len(),
        requests_completed: res.metrics.requests.len(),
    }
}

/// Drive the same trace through a polling driver in the style of
/// `server::real::RealServer::serve`: no event queue — the driver holds a
/// [`ManualClock`], advances it to the next thing that happens (an arrival,
/// an engine finishing its iteration, a refresh tick), and calls the same
/// coordinator methods the real driver calls. Engines "block" for their
/// iteration duration the way a wall-clock engine blocks on compute.
fn drive_polling(
    fleet: &FleetSpec,
    scheduler: &str,
    dispatcher: &str,
    arrivals: Vec<ArrivalEvent>,
    refresh_interval: f64,
) -> DriverTrace {
    drive_polling_elastic(
        fleet,
        scheduler,
        dispatcher,
        arrivals,
        refresh_interval,
        None,
        None,
        None,
        None,
        None,
        1,
    )
}

#[allow(clippy::too_many_arguments)]
fn drive_polling_elastic(
    fleet: &FleetSpec,
    scheduler: &str,
    dispatcher: &str,
    arrivals: Vec<ArrivalEvent>,
    refresh_interval: f64,
    autoscale: Option<AutoscaleConfig>,
    pressure: Option<PressureTrace>,
    affinity: Option<AffinitySpec>,
    route: Option<RoutePolicy>,
    cache: Option<CacheTuning>,
    threads: usize,
) -> DriverTrace {
    // Mirror `SimServer::with_fleet`: an enabled cache tuning stamps the
    // block budget onto every spec that does not carry its own, so both
    // drivers boot identical engines.
    let mut booted = fleet.clone();
    if let Some(c) = cache {
        if c.enabled {
            for s in &mut booted.instances {
                if s.cache_blocks == 0 {
                    s.cache_blocks = c.budget_blocks;
                }
            }
        }
    }
    let mut coord = Coordinator::sim(
        booted,
        make_policy(scheduler),
        make_dispatcher_tuned(dispatcher, fleet, route.as_ref(), cache.as_ref()),
    );
    if let Some(a) = autoscale {
        coord.set_autoscaler(Autoscaler::new(a));
    }
    if let Some(p) = pressure {
        coord.set_pressure(p);
    }
    if let Some(aff) = &affinity {
        coord.set_affinity(aff);
    }
    if let Some(r) = route {
        coord.set_route_policy(r);
    }
    coord.set_pump_threads(threads);
    let clock = ManualClock::new();
    let n = coord.n_instances();
    // Per-engine in-flight iteration: completes at `.0`, with outcome `.1`.
    let mut in_flight: Vec<Option<(f64, StepOutcome)>> = (0..n).map(|_| None).collect();
    let mut next_arrival = 0usize;
    let mut next_refresh = refresh_interval;

    // Start (or re-start) every idle engine that has work at time `t`.
    fn start_idle<B: kairos::engine::core::ExecBackend>(
        coord: &mut Coordinator<B>,
        in_flight: &mut [Option<(f64, StepOutcome)>],
        t: f64,
    ) {
        for j in 0..coord.n_instances() {
            if in_flight[j].is_none() && coord.engines[j].has_work() {
                let out = coord.step_engine(j, t);
                if out.duration > 0.0 {
                    in_flight[j] = Some((t + out.duration, out));
                } else {
                    coord.drain_stuck(j);
                }
            }
        }
    }

    let mut guard: u64 = 0;
    loop {
        guard += 1;
        assert!(guard < 10_000_000, "polling driver livelocked");
        // The next thing that happens, in deterministic priority order on
        // (time, kind): arrival, engine completion (lowest instance), then
        // refresh. Exact ties do not occur with continuous arrival times
        // and cost-model durations.
        let t_arrival = arrivals.get(next_arrival).map(|a| a.at).unwrap_or(f64::INFINITY);
        let (t_done, j_done) = in_flight
            .iter()
            .enumerate()
            .filter_map(|(j, f)| f.as_ref().map(|(t, _)| (*t, j)))
            .fold((f64::INFINITY, usize::MAX), |best, (t, j)| {
                if t < best.0 { (t, j) } else { best }
            });
        let t_next = t_arrival.min(t_done).min(next_refresh);
        if !t_next.is_finite() {
            break;
        }
        clock.advance_to(t_next);
        let now = clock.now();

        // A provisioned instance whose boot delay elapsed registers inside
        // pump, so the fleet can grow on ANY pump — resize afterwards.
        if t_arrival <= t_done && t_arrival <= next_refresh {
            coord.submit_plan_with_session(
                arrivals[next_arrival].plan.clone(),
                arrivals[next_arrival].session,
                now,
            );
            next_arrival += 1;
            coord.pump(now);
            while in_flight.len() < coord.n_instances() {
                in_flight.push(None);
            }
            start_idle(&mut coord, &mut in_flight, now);
        } else if t_done <= next_refresh {
            let (_, out) = in_flight[j_done].take().expect("engine was in flight");
            coord.absorb(j_done, out, now);
            coord.pump(now);
            while in_flight.len() < coord.n_instances() {
                in_flight.push(None);
            }
            start_idle(&mut coord, &mut in_flight, now);
        } else {
            coord.refresh(now);
            // The seam is also where the structural invariants are
            // audited: every refresh tick of the polling driver checks the
            // FamilyIndex and pressure cache against from-scratch rebuilds.
            let violations = coord.audit_invariants();
            assert!(
                violations.is_empty(),
                "invariant audit failed at t={now}: {violations:?}"
            );
            coord.pump(now);
            // The autoscaler (or a completed boot) may have grown the
            // fleet on this tick.
            while in_flight.len() < coord.n_instances() {
                in_flight.push(None);
            }
            start_idle(&mut coord, &mut in_flight, now);
            let more = next_arrival < arrivals.len()
                || in_flight.iter().any(Option::is_some);
            next_refresh = if coord.open_workflows() > 0 || more {
                now + refresh_interval
            } else {
                f64::INFINITY
            };
        }
    }

    // Mirror the discrete-event driver: close out still-draining
    // instances at end of run.
    coord.finalize_drained(clock.now());

    DriverTrace {
        dispatch_log: coord.dispatch_log.take_vec(),
        group_log: coord.group_log.take_vec(),
        route_log: coord.route_log.take_vec(),
        scale_log: coord
            .scale_log
            .iter()
            .map(|e| (e.kind, e.instance, e.dispatch_seq))
            .collect(),
        trace_log: coord.trace_log.take_vec(),
        dropped: coord.dropped,
        workflows_completed: coord.metrics.workflows.len(),
        requests_completed: coord.metrics.requests.len(),
    }
}

#[test]
fn sim_and_polling_drivers_make_identical_decisions() {
    let fleet = FleetSpec::parse("2*llama3-8b@0.12").unwrap();
    for (sched, disp) in [("parrot", "rr"), ("kairos", "kairos"), ("kairos", "least")] {
        let arrivals = trace(4.0, 120, 21);
        let a = drive_sim(&fleet, sched, disp, arrivals.clone());
        let b = drive_polling(&fleet, sched, disp, arrivals, 5.0);
        assert!(!a.dispatch_log.is_empty());
        assert_eq!(
            a, b,
            "{sched}/{disp}: drivers diverged over the same coordinator"
        );
    }
}

#[test]
fn seam_holds_on_heterogeneous_fleet() {
    // Uneven co-tenant pressure: the per-instance budget path must behave
    // identically under both drivers too.
    let fleet = FleetSpec::parse("llama3-8b@0.12,llama3-8b@0.04:128").unwrap();
    let arrivals = trace(3.0, 100, 22);
    let a = drive_sim(&fleet, "kairos", "kairos", arrivals.clone());
    let b = drive_polling(&fleet, "kairos", "kairos", arrivals, 5.0);
    assert!(!a.dispatch_log.is_empty());
    assert_eq!(a, b);
}

fn elastic_config(fleet: &FleetSpec) -> AutoscaleConfig {
    AutoscaleConfig {
        min_instances: fleet.len(),
        max_instances: fleet.len() + 2,
        queue_high: 4.0,
        queue_low: 1.0,
        ratio_high: 0.6,
        up_after: 1,
        down_after: 2,
        cooldown: 5.0,
        boot_delay: 0.0,
        boot_delay_per_group: Vec::new(),
        per_group: Vec::new(),
        template: fleet.instances[0],
    }
}

#[test]
fn fleet_resize_seam_holds_across_drivers() {
    // The resize contract: the same trace + the same (deterministic,
    // refresh-driven) scale events through the event-driven and polling
    // drivers produce identical dispatch logs — and identical fleet
    // reshaping relative to the dispatch stream.
    let fleet = FleetSpec::parse("2*llama3-8b@0.12").unwrap();
    let auto = elastic_config(&fleet);
    let pressure = PressureTrace::parse("*:0=1.0,15=0.7,45=1.0").unwrap();
    let arrivals = burst_then_calm(31);
    let a = drive_sim_elastic(
        &fleet,
        "kairos",
        "kairos",
        arrivals.clone(),
        Some(auto.clone()),
        Some(pressure.clone()),
        None,
        None,
    );
    let b = drive_polling_elastic(
        &fleet,
        "kairos",
        "kairos",
        arrivals,
        5.0,
        Some(auto),
        Some(pressure),
        None,
        None,
        None,
        1,
    );
    assert!(!a.dispatch_log.is_empty());
    assert!(
        a.scale_log.iter().any(|&(k, _, _)| k == ScaleEventKind::Grow),
        "burst must grow the fleet: {:?}",
        a.scale_log
    );
    assert_eq!(a, b, "drivers diverged over the elastic coordinator");
}

#[test]
fn no_request_ever_dispatched_to_a_retired_instance() {
    let fleet = FleetSpec::parse("2*llama3-8b@0.12").unwrap();
    let auto = elastic_config(&fleet);
    let res = {
        let mut cfg = FleetConfig::from(fleet.clone());
        cfg.autoscale = Some(auto);
        run_fleet(cfg, "kairos", "kairos", burst_then_calm(32))
    };
    assert_eq!(res.dropped_requests, 0, "draining must not drop requests");
    let retire_starts: Vec<_> = res
        .scale_log
        .iter()
        .filter(|e| e.kind == ScaleEventKind::RetireStart)
        .collect();
    assert!(
        !retire_starts.is_empty(),
        "calm tail must drain the grown fleet: {:?}",
        res.scale_log
    );
    // No grow fires after the calm tail's retire-starts in this trace
    // (a tombstone CAN be revived by a later same-family grow, but the
    // burst is over), so from each retire-start onward its instance must
    // be absent from the dispatch log.
    for ev in retire_starts {
        assert!(
            res.dispatch_log[ev.dispatch_seq..]
                .iter()
                .all(|&(_, j)| j != ev.instance),
            "request dispatched to instance {} after its retirement",
            ev.instance
        );
    }
}

#[test]
fn sharded_seam_holds_on_mixed_model_fleet() {
    // The sharded contract: agents pinned to model families, a mixed fleet
    // — both drivers must produce identical per-group dispatch logs, and
    // no request may land on a model-incompatible instance.
    let fleet = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.12").unwrap();
    let aff = AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b,QAEngineer=llama2-13b")
        .unwrap();
    let arrivals = trace(3.0, 120, 41);
    let a = drive_sim_elastic(
        &fleet,
        "kairos",
        "kairos",
        arrivals.clone(),
        None,
        None,
        Some(aff.clone()),
        None,
    );
    let b = drive_polling_elastic(
        &fleet,
        "kairos",
        "kairos",
        arrivals,
        5.0,
        None,
        None,
        Some(aff),
        None,
        None,
        1,
    );
    assert!(!a.dispatch_log.is_empty());
    assert_eq!(a, b, "drivers diverged over the sharded coordinator");
    // The pinned group saw traffic, and every dispatch stayed in-family.
    let pinned = ModelClass::Model(ModelKind::Llama2_13B);
    assert!(
        a.group_log.iter().any(|g| g.class == pinned),
        "13B-pinned agents never dispatched: {:?}",
        a.group_log.len()
    );
    for g in &a.group_log {
        assert!(
            g.class.matches(g.model),
            "request {} pinned to {:?} dispatched to a {:?} instance",
            g.req,
            g.class,
            g.model
        );
    }
    // Per-group logs (views of the group log) are identical across
    // drivers by construction; spot-check the pinned group's view.
    let group_view = |t: &DriverTrace| -> Vec<(u64, usize)> {
        t.group_log
            .iter()
            .filter(|g| g.class == pinned)
            .map(|g| (g.req, g.instance))
            .collect()
    };
    assert_eq!(group_view(&a), group_view(&b));
    assert!(!group_view(&a).is_empty());
}

#[test]
fn route_log_seam_holds_with_learned_routing_and_group_bounds() {
    // The routing-layer contract: on a mixed-model trace with LEARNED
    // routing (profile-driven pins, pressure-balanced Any placement,
    // deterministic exploration), per-group autoscale bounds AND a boot
    // delay, both drivers must produce identical route, group, dispatch
    // and scale logs — and the zero-cross-model-dispatch pump assert
    // still holds.
    let fleet = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.12").unwrap();
    let aff =
        AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b,QAEngineer=llama2-13b").unwrap();
    let mut auto = elastic_config(&fleet);
    auto.boot_delay = 4.0;
    auto.per_group = parse_per_group("llama3-8b=2..4,llama2-13b=1..2").unwrap();
    let route = RoutePolicy::Learned { explore_rate: 0.125, min_samples: 8 };
    let arrivals = burst_then_calm(43);
    let a = drive_sim_elastic(
        &fleet,
        "kairos",
        "kairos",
        arrivals.clone(),
        Some(auto.clone()),
        None,
        Some(aff.clone()),
        Some(route),
    );
    let b = drive_polling_elastic(
        &fleet,
        "kairos",
        "kairos",
        arrivals,
        5.0,
        Some(auto),
        None,
        Some(aff),
        Some(route),
        None,
        1,
    );
    assert!(!a.dispatch_log.is_empty());
    // Route decisions are per submitted stage: unique per request, and a
    // superset of the dispatched requests. (No exact arithmetic against
    // `dropped`: an engine-side drain_stuck drop counts a request that
    // was already dispatched.)
    let routed: std::collections::HashSet<u64> = a.route_log.iter().map(|d| d.req).collect();
    assert_eq!(routed.len(), a.route_log.len(), "one route decision per request");
    assert!(
        a.dispatch_log.iter().all(|(id, _)| routed.contains(id)),
        "a request was dispatched without a route decision"
    );
    assert_eq!(a, b, "drivers diverged under learned routing");
    // The pump-level invariant survives learned stamps: no request ever
    // lands on a model family it was not (re-)pinned to.
    for g in &a.group_log {
        assert!(
            g.class.matches(g.model),
            "request {} class {:?} dispatched to {:?}",
            g.req,
            g.class,
            g.model
        );
    }
    // The learned machinery actually engaged: exploration fired, and the
    // profiles eventually produced learned-best stamps.
    assert!(
        a.route_log.iter().any(|d| d.reason == RouteReason::Explore),
        "no exploration decision in {} routes",
        a.route_log.len()
    );
    assert!(
        a.route_log.iter().any(|d| d.reason == RouteReason::LearnedBest),
        "profiles never converged to a learned stamp"
    );
}

#[test]
fn record_replay_round_trip_reproduces_both_drivers() {
    // The record→replay contract: a trace recorded from a sim run, written
    // to JSONL, reloaded, and replayed through BOTH drivers reproduces the
    // original dispatch, route, and group logs exactly.
    let fleet = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.12").unwrap();
    let aff =
        AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b,QAEngineer=llama2-13b")
            .unwrap();
    let arrivals = trace(3.0, 100, 51);
    let original = drive_sim_elastic(
        &fleet,
        "kairos",
        "kairos",
        arrivals,
        None,
        None,
        Some(aff.clone()),
        None,
    );
    assert_eq!(original.trace_log.len(), 100, "every submitted plan recorded");
    // Serialize the recorded run, write it out, and reload it — the
    // artifact any other session could replay.
    let recorded = Trace::from_records(original.trace_log.clone());
    let path = std::env::temp_dir().join("kairos_seam_record_replay.jsonl");
    recorded.save(&path).unwrap();
    let reloaded = Trace::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(reloaded, recorded, "JSONL round trip is identity");
    // The recorded stamps reflect the affinity config: pinned stages
    // carry their class.
    assert!(reloaded
        .records
        .iter()
        .flat_map(|r| r.stages.iter())
        .any(|s| s.class == Some(ModelClass::Model(ModelKind::Llama2_13B))));
    // Replay through the discrete-event driver AND the polling driver.
    let replay_sim = drive_sim_elastic(
        &fleet,
        "kairos",
        "kairos",
        reloaded.arrivals(),
        None,
        None,
        Some(aff.clone()),
        None,
    );
    let replay_poll = drive_polling_elastic(
        &fleet,
        "kairos",
        "kairos",
        reloaded.arrivals(),
        5.0,
        None,
        None,
        Some(aff),
        None,
        None,
        1,
    );
    assert_eq!(
        replay_sim, original,
        "sim replay diverged from the recorded run"
    );
    assert_eq!(
        replay_poll, original,
        "polling replay diverged from the recorded run"
    );
    // Idempotence: replaying the recording re-records the same trace.
    assert_eq!(replay_sim.trace_log, original.trace_log);
}

#[test]
fn timeslot_respects_per_instance_budgets_end_to_end() {
    // One full instance and one squeezed to ~2% of the pool. Under the
    // memory-aware time-slot dispatcher, the squeezed instance must
    // receive a strictly smaller share of dispatches, and nothing drops.
    let fleet = FleetSpec::parse("llama3-8b@0.12,llama3-8b@0.02").unwrap();
    let arrivals = trace(3.0, 150, 23);
    let res = run_fleet(FleetConfig::from(fleet), "kairos", "kairos", arrivals);
    assert!(res.summary.n_workflows > 0);
    let to_small =
        res.dispatch_log.iter().filter(|&&(_, j)| j == 1).count();
    let to_big = res.dispatch_log.iter().filter(|&&(_, j)| j == 0).count();
    assert!(to_big > 0);
    assert!(
        to_small < to_big,
        "squeezed instance got {to_small} of {} dispatches",
        to_small + to_big
    );
}

#[test]
fn ring_buffer_logging_preserves_dispatch_decisions() {
    // The logging seam: capping the coordinator logs (and running lean
    // metrics) must not change a single dispatch decision — only how many
    // of them are retained at the end of the run.
    let fleet = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.12").unwrap();
    let aff =
        AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b,QAEngineer=llama2-13b")
            .unwrap();
    let arrivals = trace(3.0, 120, 61);
    let run = |logs: LogConfig, lean: bool| {
        let mut cfg = FleetConfig::from(fleet.clone());
        cfg.affinity = Some(aff.clone());
        cfg.logs = logs;
        cfg.lean_metrics = lean;
        run_fleet(cfg, "kairos", "kairos", arrivals.clone())
    };
    let full = run(LogConfig::full(), false);
    let capped = run(LogConfig::bounded(32), true);

    // Same decision stream length (the ring's total survives eviction)...
    assert_eq!(full.dispatch_log.len() as u64, full.dispatched_total);
    assert_eq!(capped.dispatched_total, full.dispatched_total);
    assert_eq!(capped.dropped_requests, full.dropped_requests);
    assert!(full.dispatch_log.len() > 32, "trace too small to evict");
    // ...with exactly the newest 32 entries of each log retained.
    assert_eq!(capped.dispatch_log.len(), 32);
    let n = full.dispatch_log.len();
    assert_eq!(capped.dispatch_log, full.dispatch_log[n - 32..]);
    assert_eq!(capped.group_log, full.group_log[full.group_log.len() - 32..]);
    assert_eq!(capped.route_log, full.route_log[full.route_log.len() - 32..]);
    assert_eq!(capped.trace_log, full.trace_log[full.trace_log.len() - 32..]);
    assert!(
        capped.log_state_bytes < full.log_state_bytes,
        "capped logs should retain less state: {} vs {}",
        capped.log_state_bytes,
        full.log_state_bytes
    );

    // Lean metrics retain nothing, count everything, and the streaming
    // summary tracks the exact one (mean exactly, percentiles via P²).
    assert!(capped.metrics.requests.is_empty());
    assert_eq!(capped.metrics.total_requests, full.metrics.total_requests);
    assert_eq!(capped.metrics.total_workflows, full.metrics.total_workflows);
    let exact = full.metrics.summary().unwrap();
    let sketch = capped.metrics.streaming_summary().unwrap();
    assert_eq!(sketch.n_workflows, exact.n_workflows);
    assert!((sketch.avg_token_latency - exact.avg_token_latency).abs() < 1e-9);
    assert!((sketch.mean_queue_ratio - exact.mean_queue_ratio).abs() < 1e-9);
    let rel = (sketch.p50_token_latency - exact.p50_token_latency).abs()
        / exact.p50_token_latency.max(1e-9);
    assert!(rel < 0.5, "P² median drifted {rel} from exact");
}

#[test]
fn invariant_audits_hold_through_an_elastic_sim_run() {
    // The discrete-event counterpart of the polling driver's per-refresh
    // audit: `SimServer::enable_audit` checks the FamilyIndex slot sets,
    // the pressure cache, and tombstone exclusion on every refresh tick of
    // a run that grows, drains, and retires instances — the regime where
    // the incrementally-maintained structures could drift.
    let fleet = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.12").unwrap();
    let aff =
        AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b,QAEngineer=llama2-13b")
            .unwrap();
    let mut auto = elastic_config(&fleet);
    auto.per_group = parse_per_group("llama3-8b=2..4,llama2-13b=1..2").unwrap();
    let mut cfg = FleetConfig::from(fleet.clone());
    cfg.autoscale = Some(auto);
    cfg.affinity = Some(aff);
    let mut server = SimServer::with_fleet(
        cfg,
        make_policy("kairos"),
        make_dispatcher_for_fleet("kairos", &fleet),
    );
    server.enable_audit();
    let res = server.run(burst_then_calm(71));
    assert!(res.audit_checks > 0, "audits must actually run");
    assert!(res.audit_violations.is_empty(), "{:?}", res.audit_violations);
    assert!(
        res.scale_log.iter().any(|e| e.kind == ScaleEventKind::Grow),
        "burst must reshape the fleet so the audit covers churn"
    );
}

#[test]
fn legacy_and_indexed_hot_paths_are_identical_through_the_driver() {
    // The hot-path contract: the per-family candidate index, the cached
    // group pressures, and the batched stale-snapshot refresh are pure
    // speedups. The retained legacy scan must make identical decisions
    // across a mixed fleet that grows, drains, and retires under learned
    // routing — the regime where every optimized structure is exercised.
    let fleet = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.12").unwrap();
    let aff =
        AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b,QAEngineer=llama2-13b")
            .unwrap();
    let mut auto = elastic_config(&fleet);
    auto.boot_delay = 4.0;
    auto.per_group = parse_per_group("llama3-8b=2..4,llama2-13b=1..2").unwrap();
    let arrivals = burst_then_calm(67);
    let run = |legacy: bool| {
        let mut cfg = FleetConfig::from(fleet.clone());
        cfg.autoscale = Some(auto.clone());
        cfg.affinity = Some(aff.clone());
        cfg.route = Some(RoutePolicy::Learned { explore_rate: 0.125, min_samples: 8 });
        cfg.legacy_hot_path = legacy;
        run_fleet(cfg, "kairos", "kairos", arrivals.clone())
    };
    let legacy = run(true);
    let indexed = run(false);
    assert!(!legacy.dispatch_log.is_empty());
    assert!(
        legacy.scale_log.iter().any(|e| e.kind == ScaleEventKind::Grow),
        "burst must reshape the fleet to exercise index maintenance"
    );
    assert_eq!(legacy.dispatch_log, indexed.dispatch_log);
    assert_eq!(legacy.group_log, indexed.group_log);
    assert_eq!(legacy.route_log, indexed.route_log);
    let scale = |r: &kairos::server::sim::SimResult| -> Vec<(ScaleEventKind, usize, usize)> {
        r.scale_log
            .iter()
            .map(|e| (e.kind, e.instance, e.dispatch_seq))
            .collect()
    };
    assert_eq!(scale(&legacy), scale(&indexed));
    assert_eq!(legacy.dropped_requests, indexed.dropped_requests);
    assert_eq!(legacy.dispatched_total, indexed.dispatched_total);
    assert_eq!(
        legacy.metrics.requests.len(),
        indexed.metrics.requests.len()
    );
    assert_eq!(
        legacy.metrics.workflows.len(),
        indexed.metrics.workflows.len()
    );
}

#[test]
fn scoring_arms_and_candidate_pruning_are_identical_through_the_driver() {
    // The packer's scoring A/B (`set_legacy_scoring`: naive linear peak
    // scans vs the max-tree fast paths) and the coordinator's candidate
    // seam (`choose_among` fed from the FamilyIndex vs full-scan `choose`
    // on the legacy hot path) are both pure speedups. Run the full
    // (hot_path × scoring) matrix over a mixed elastic fleet under learned
    // routing — the regime where pinned requests flow through the pruned
    // entry point and near-capacity packing exercises every fast-path
    // band — with invariant audits on: all four runs must produce one
    // decision stream.
    let fleet = FleetSpec::parse("2*llama3-8b@0.12,llama2-13b@0.12").unwrap();
    let aff =
        AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b,QAEngineer=llama2-13b")
            .unwrap();
    let mut auto = elastic_config(&fleet);
    auto.per_group = parse_per_group("llama3-8b=2..4,llama2-13b=1..2").unwrap();
    let arrivals = burst_then_calm(67);
    let run = |legacy_hot_path: bool, legacy_scoring: bool| {
        let mut cfg = FleetConfig::from(fleet.clone());
        cfg.autoscale = Some(auto.clone());
        cfg.affinity = Some(aff.clone());
        cfg.route = Some(RoutePolicy::Learned { explore_rate: 0.125, min_samples: 8 });
        cfg.legacy_hot_path = legacy_hot_path;
        cfg.legacy_scoring = legacy_scoring;
        let route = cfg.route;
        let mut server = SimServer::with_fleet(
            cfg,
            make_policy("kairos"),
            make_dispatcher_routed("kairos", &fleet, route.as_ref()),
        );
        server.enable_audit();
        server.run(arrivals.clone())
    };
    let reference = run(false, false);
    assert!(!reference.dispatch_log.is_empty());
    assert!(reference.audit_checks > 0, "audits must actually run");
    assert!(
        reference.audit_violations.is_empty(),
        "{:?}",
        reference.audit_violations
    );
    let p = reference.metrics.stream.packer;
    assert!(p.decisions > 0, "packer stats must flow to the metrics surface");
    assert!(
        p.fast_accepted + p.fast_rejected > 0,
        "a packing-heavy run must hit the max-tree fast paths"
    );
    for (hot, scoring) in [(false, true), (true, false), (true, true)] {
        let arm = run(hot, scoring);
        assert_eq!(
            reference.dispatch_log, arm.dispatch_log,
            "dispatch log diverged at hot_path={hot} scoring={scoring}"
        );
        assert_eq!(reference.group_log, arm.group_log);
        assert_eq!(reference.route_log, arm.route_log);
        assert_eq!(reference.dropped_requests, arm.dropped_requests);
        assert_eq!(reference.dispatched_total, arm.dispatched_total);
        assert!(arm.audit_violations.is_empty(), "{:?}", arm.audit_violations);
        if scoring {
            let lp = arm.metrics.stream.packer;
            assert_eq!(
                lp.fast_accepted + lp.fast_rejected,
                0,
                "legacy scoring must never take a fast path"
            );
        }
    }
}

#[test]
fn cache_affine_seam_holds_with_audits_on() {
    // The prefix-cache contract across the runtime seam: a session-keyed
    // trace through the session-sticky `cache-affine` dispatcher (CHWBL
    // over the kairos packer) must produce byte-identical dispatch, group
    // and route logs from the discrete-event and polling drivers — with
    // the cache enabled in the engines (so hits shorten prefill and feed
    // back into timing) and the prefix-cache bookkeeping audits green in
    // both drivers.
    let fleet = FleetSpec::parse("3*llama3-8b@0.12").unwrap();
    let mut arrivals = trace(4.0, 120, 81);
    for (i, a) in arrivals.iter_mut().enumerate() {
        a.session = Some(i as u64 % 10);
    }
    let tuning = CacheTuning { enabled: true, budget_blocks: 128, load_factor: 1.25 };

    // Discrete-event reference, audited on every refresh tick.
    let mut cfg = FleetConfig::from(fleet.clone());
    cfg.cache = tuning;
    let mut server = SimServer::with_fleet(
        cfg,
        make_policy("kairos"),
        make_dispatcher_tuned("cache-affine", &fleet, None, Some(&tuning)),
    );
    server.enable_audit();
    let res = server.run(arrivals.clone());
    assert!(res.audit_checks > 0, "audits must actually run");
    assert!(res.audit_violations.is_empty(), "{:?}", res.audit_violations);
    assert!(
        res.cache_stats().hits > 0,
        "a session-heavy stream must hit the prefix cache"
    );
    assert!(
        res.metrics.stream.packer.sticky_hits > 0,
        "CHWBL never stuck a session to its instance"
    );
    let a = driver_trace_of(res);

    // The polling driver audits on every refresh tick internally.
    let b = drive_polling_elastic(
        &fleet,
        "kairos",
        "cache-affine",
        arrivals,
        5.0,
        None,
        None,
        None,
        None,
        Some(tuning),
        1,
    );
    assert!(!a.dispatch_log.is_empty());
    assert_eq!(a, b, "drivers diverged under session-sticky dispatch");
}

#[test]
fn parallel_pump_keeps_the_seam_at_every_thread_count() {
    // The parallel-pump contract across the DRIVER seam: the same sharded
    // mixed-model trace through the discrete-event driver and the polling
    // driver (which audits the structural invariants on every refresh
    // tick), at 1, 2 and 4 pump workers — every combination must produce
    // the sequential reference run's exact DriverTrace.
    let fleet = FleetSpec::parse("2*llama3-8b@0.12,2*llama2-13b@0.12").unwrap();
    let aff = AffinitySpec::parse("*=llama3-8b,Engineer=llama2-13b,QAEngineer=llama2-13b")
        .unwrap();
    let arrivals = trace(6.0, 140, 53);
    let base = {
        let mut cfg = FleetConfig::from(fleet.clone());
        cfg.affinity = Some(aff.clone());
        driver_trace_of(run_fleet(cfg, "kairos", "kairos", arrivals.clone()))
    };
    assert!(!base.dispatch_log.is_empty());
    for threads in [2usize, 4] {
        let sim_par = {
            let mut cfg = FleetConfig::from(fleet.clone());
            cfg.affinity = Some(aff.clone());
            cfg.threads = threads;
            driver_trace_of(run_fleet(cfg, "kairos", "kairos", arrivals.clone()))
        };
        assert_eq!(
            base, sim_par,
            "sim driver's parallel pump diverged at {threads} threads"
        );
        let poll_par = drive_polling_elastic(
            &fleet,
            "kairos",
            "kairos",
            arrivals.clone(),
            5.0,
            None,
            None,
            Some(aff.clone()),
            None,
            None,
            threads,
        );
        assert_eq!(
            base, poll_par,
            "polling driver's parallel pump diverged at {threads} threads"
        );
    }
}
