//! Repo-specific determinism lints for the Kairos reproduction.
//!
//! The simulator's core promise is bit-for-bit reproducibility: the same
//! trace and seed must produce the same dispatch decisions on every run.
//! The compiler cannot enforce the conventions that promise rests on —
//! no wall-clock reads outside the `WallClock` seam, no iteration over
//! hash-ordered containers in decision paths, total float comparisons,
//! no ambient randomness — so this crate does, as `syn`-level AST passes
//! with `file:line:col` diagnostics.
//!
//! Each rule carries a stable kebab-case id (see [`rules`]). A violation
//! can be waived in place with a suppression comment on the line above
//! (or the same line as) the offending code:
//!
//! ```text
//! // kairos-lint: allow(rule-id, why this site is legitimately exempt)
//! ```
//!
//! The reason is mandatory — an allow without one is itself an error. The
//! CI `lint` job runs `cargo run -p kairos-lint -- --root rust/src` and
//! fails on any diagnostic.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

pub mod rules;

/// Rule id reported for malformed or reason-less suppression comments.
pub const SUPPRESSION_RULE: &str = "suppression";

/// One lint finding, anchored to a source location.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable kebab-case rule id (e.g. `wall-clock`).
    pub rule: &'static str,
    /// File path as given to the engine (relative, forward slashes).
    pub file: String,
    /// 1-based line of the offending code.
    pub line: usize,
    /// 1-based column of the offending code.
    pub col: usize,
    /// What is wrong and what to do instead.
    pub message: String,
    /// The offending source line, trimmed.
    pub snippet: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "{}:{}:{}: [{}] {}",
            self.file, self.line, self.col, self.rule, self.message
        )?;
        write!(f, "    |  {}", self.snippet)
    }
}

/// A rule finding before it is bound to a file and filtered against
/// suppressions.
#[derive(Debug, Clone)]
pub struct RawDiag {
    /// 1-based line.
    pub line: usize,
    /// 1-based column.
    pub col: usize,
    /// Diagnostic text.
    pub message: String,
}

/// Everything a rule may inspect about one source file.
pub struct FileCtx<'a> {
    /// Path relative to the lint root, forward slashes.
    pub rel: &'a str,
    /// Raw source text.
    pub src: &'a str,
    /// `src` split into lines (0-indexed; line N of a span is `lines[N-1]`).
    pub lines: &'a [&'a str],
    /// Parsed AST.
    pub ast: &'a syn::File,
}

/// One determinism lint: an id, a path scope, and an AST check.
pub trait Rule {
    /// Stable kebab-case id used in diagnostics and suppression comments.
    fn id(&self) -> &'static str;
    /// One-line description for `--list-rules`.
    fn description(&self) -> &'static str;
    /// Whether the rule runs on this file (path relative to the root).
    fn applies_to(&self, rel: &str) -> bool;
    /// Run the check and report findings.
    fn check(&self, ctx: &FileCtx) -> Vec<RawDiag>;
}

/// A parsed `// kairos-lint: allow(rule, reason)` marker.
#[derive(Debug, Clone)]
struct Suppression {
    /// 1-based line the comment sits on.
    line: usize,
    /// The rule id it waives.
    rule: String,
}

const MARKER: &str = "kairos-lint:";

/// Scan the raw source for suppression markers. Returns the valid
/// suppressions and an error diagnostic for every malformed or
/// reason-less marker (those errors are never themselves suppressible).
fn parse_suppressions(lines: &[&str]) -> (Vec<Suppression>, Vec<RawDiag>) {
    let mut sups = Vec::new();
    let mut errors = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let Some(pos) = raw.find(MARKER) else { continue };
        let line = i + 1;
        let col = pos + 1;
        let rest = raw[pos + MARKER.len()..].trim_start();
        let body = rest
            .strip_prefix("allow(")
            .and_then(|inner| inner.rfind(')').map(|end| &inner[..end]));
        let Some(body) = body else {
            errors.push(RawDiag {
                line,
                col,
                message: format!(
                    "malformed suppression — expected `// {MARKER} allow(rule-id, reason)`"
                ),
            });
            continue;
        };
        match body.split_once(',') {
            Some((rule, reason)) if !reason.trim().is_empty() => sups.push(Suppression {
                line,
                rule: rule.trim().to_string(),
            }),
            _ => errors.push(RawDiag {
                line,
                col,
                message: format!(
                    "suppression needs a reason — `// {MARKER} allow(rule-id, reason)`"
                ),
            }),
        }
    }
    (sups, errors)
}

/// Whether a diagnostic of `rule` at `line` is waived: a matching allow
/// marker on the same line, or on a directly preceding line in an
/// unbroken run of comments and attributes.
fn is_suppressed(
    by_line: &BTreeMap<usize, Vec<String>>,
    lines: &[&str],
    rule: &str,
    line: usize,
) -> bool {
    let matches_at = |l: usize| {
        by_line
            .get(&l)
            .is_some_and(|rules| rules.iter().any(|r| r == rule))
    };
    if matches_at(line) {
        return true;
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        let text = lines.get(l - 1).map(|s| s.trim()).unwrap_or("");
        if !(text.starts_with("//") || text.starts_with("#[") || text.starts_with("#!")) {
            return false;
        }
        if matches_at(l) {
            return true;
        }
    }
    false
}

/// The full rule set, in catalog order.
pub fn default_rules() -> Vec<Box<dyn Rule>> {
    rules::all()
}

/// Lint one file's source text against `rules`. `rel` decides path
/// scoping, so callers must pass the path relative to the lint root.
pub fn lint_source(rel: &str, src: &str, rules: &[Box<dyn Rule>]) -> Vec<Diagnostic> {
    let lines: Vec<&str> = src.lines().collect();
    let snippet_at =
        |line: usize| lines.get(line.wrapping_sub(1)).map(|s| s.trim()).unwrap_or("").to_string();
    let mut out = Vec::new();

    let (sups, sup_errors) = parse_suppressions(&lines);
    for e in sup_errors {
        out.push(Diagnostic {
            rule: SUPPRESSION_RULE,
            file: rel.to_string(),
            line: e.line,
            col: e.col,
            message: e.message,
            snippet: snippet_at(e.line),
        });
    }
    let mut by_line: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    for s in &sups {
        by_line.entry(s.line).or_default().push(s.rule.clone());
    }

    let ast = match syn::parse_file(src) {
        Ok(ast) => ast,
        Err(e) => {
            let start = e.span().start();
            out.push(Diagnostic {
                rule: "parse",
                file: rel.to_string(),
                line: start.line,
                col: start.column + 1,
                message: format!("file does not parse: {e}"),
                snippet: snippet_at(start.line),
            });
            return out;
        }
    };
    let ctx = FileCtx { rel, src, lines: &lines, ast: &ast };
    for rule in rules {
        if !rule.applies_to(rel) {
            continue;
        }
        for d in rule.check(&ctx) {
            if is_suppressed(&by_line, &lines, rule.id(), d.line) {
                continue;
            }
            out.push(Diagnostic {
                rule: rule.id(),
                file: rel.to_string(),
                line: d.line,
                col: d.col,
                message: d.message,
                snippet: snippet_at(d.line),
            });
        }
    }
    out.sort_by_key(|d| (d.line, d.col, d.rule));
    out
}

/// Recursively lint every `.rs` file under `root` (deterministic file
/// order). Paths in diagnostics are relative to `root`.
pub fn lint_root(root: &Path, rules: &[Box<dyn Rule>]) -> anyhow::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in files {
        let src = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        out.extend(lint_source(&rel, &src, rules));
    }
    Ok(out)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> anyhow::Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(rel: &str, src: &str) -> Vec<Diagnostic> {
        lint_source(rel, src, &default_rules())
    }

    #[test]
    fn suppression_with_reason_waives_the_next_line() {
        let src = "fn f() {\n\
                   \x20   // kairos-lint: allow(wall-clock, timing a real run)\n\
                   \x20   let _t = std::time::Instant::now();\n\
                   }\n";
        assert!(lint("lb/x.rs", src).is_empty());
    }

    #[test]
    fn suppression_without_reason_is_an_error() {
        let src = "fn f() {\n\
                   \x20   // kairos-lint: allow(wall-clock)\n\
                   \x20   let _t = std::time::Instant::now();\n\
                   }\n";
        let diags = lint("lb/x.rs", src);
        assert!(
            diags.iter().any(|d| d.rule == SUPPRESSION_RULE),
            "reason-less allow must error: {diags:?}"
        );
        assert!(
            diags.iter().any(|d| d.rule == "wall-clock"),
            "a broken suppression must not waive the violation: {diags:?}"
        );
    }

    #[test]
    fn suppression_for_the_wrong_rule_does_not_waive() {
        let src = "fn f() {\n\
                   \x20   // kairos-lint: allow(no-env-fs, wrong rule entirely)\n\
                   \x20   let _t = std::time::Instant::now();\n\
                   }\n";
        let diags = lint("lb/x.rs", src);
        assert!(diags.iter().any(|d| d.rule == "wall-clock"), "{diags:?}");
    }

    #[test]
    fn suppression_skips_over_attributes() {
        let src = "fn f() {\n\
                   \x20   // kairos-lint: allow(wall-clock, attribute sits between)\n\
                   \x20   #[allow(clippy::disallowed_methods)]\n\
                   \x20   let _t = std::time::Instant::now();\n\
                   }\n";
        assert!(lint("lb/x.rs", src).is_empty());
    }

    #[test]
    fn diagnostics_carry_location_and_snippet() {
        let src = "fn f() {\n    let _t = std::time::Instant::now();\n}\n";
        let diags = lint("lb/x.rs", src);
        assert_eq!(diags.len(), 1);
        let d = &diags[0];
        assert_eq!(d.rule, "wall-clock");
        assert_eq!(d.line, 2);
        assert!(d.col > 1);
        assert!(d.snippet.contains("Instant::now"));
        let shown = d.to_string();
        assert!(shown.contains("lb/x.rs:2:"), "{shown}");
    }

    #[test]
    fn unparsable_file_reports_a_parse_diagnostic() {
        let diags = lint("util/x.rs", "fn f( {}\n");
        assert!(diags.iter().any(|d| d.rule == "parse"), "{diags:?}");
    }

    #[test]
    fn test_modules_are_exempt_from_every_rule() {
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                   \x20   #[test]\n\
                   \x20   fn t() {\n\
                   \x20       let _t = std::time::Instant::now();\n\
                   \x20       let x: Option<u32> = None;\n\
                   \x20       let _ = x.unwrap();\n\
                   \x20   }\n\
                   }\n";
        assert!(lint("server/x.rs", src).is_empty());
    }
}
