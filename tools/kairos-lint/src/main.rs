//! CLI for the determinism linter.
//!
//! ```text
//! cargo run -p kairos-lint -- --root rust/src [--rule ID] [--list-rules]
//! ```
//!
//! Exits 0 when the tree is clean, 1 when any diagnostic fires.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("kairos-lint: {e}");
            ExitCode::from(2)
        }
    }
}

fn run() -> anyhow::Result<ExitCode> {
    let mut root: Option<PathBuf> = None;
    let mut rule_filter: Option<String> = None;
    let mut list_rules = false;

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => {
                root = Some(PathBuf::from(args.next().ok_or_else(|| {
                    anyhow::anyhow!("--root requires a path")
                })?));
            }
            "--rule" => {
                rule_filter = Some(args.next().ok_or_else(|| {
                    anyhow::anyhow!("--rule requires a rule id")
                })?);
            }
            "--list-rules" => list_rules = true,
            "--help" | "-h" => {
                println!(
                    "usage: kairos-lint --root PATH [--rule ID] [--list-rules]\n\
                     Lints a Rust source tree for the repo's determinism rules.\n\
                     Suppress a finding in place with\n\
                     `// kairos-lint: allow(rule-id, reason)` — reason mandatory."
                );
                return Ok(ExitCode::SUCCESS);
            }
            other => anyhow::bail!("unknown argument `{other}` (try --help)"),
        }
    }

    let rules = kairos_lint::default_rules();
    if list_rules {
        for r in &rules {
            println!("{:<16} {}", r.id(), r.description());
        }
        return Ok(ExitCode::SUCCESS);
    }

    let root = root.ok_or_else(|| anyhow::anyhow!("--root PATH is required (try --help)"))?;
    if let Some(id) = &rule_filter {
        if !rules.iter().any(|r| r.id() == id) {
            anyhow::bail!("unknown rule `{id}` (see --list-rules)");
        }
    }

    let mut diags = kairos_lint::lint_root(&root, &rules)?;
    if let Some(id) = &rule_filter {
        // The suppression meta-rule always reports: a broken allow is an
        // error regardless of which rule is being filtered for.
        diags.retain(|d| d.rule == id || d.rule == kairos_lint::SUPPRESSION_RULE);
    }

    if diags.is_empty() {
        println!("kairos-lint: clean ({} rules over {})", rules.len(), root.display());
        return Ok(ExitCode::SUCCESS);
    }
    for d in &diags {
        println!("{d}");
    }
    println!("kairos-lint: {} violation(s)", diags.len());
    Ok(ExitCode::FAILURE)
}
