//! The nine determinism rules (D1–D9 in the lint catalog).
//!
//! Every rule skips `#[cfg(test)]` modules and `#[test]` functions:
//! tests may freely read clocks, unwrap, spawn threads, and iterate hash
//! maps — the rules guard the simulation and serving paths, not test
//! scaffolding.

use proc_macro2::Span;
use quote::ToTokens;
use syn::visit::{self, Visit};

use crate::{FileCtx, RawDiag, Rule};

/// All rules in catalog order (D1..D9).
pub fn all() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(WallClock),
        Box::new(UnorderedIter),
        Box::new(FloatOrd),
        Box::new(SeededRand),
        Box::new(UnboundedLog),
        Box::new(HotPathPanic),
        Box::new(MissingDocs),
        Box::new(NoEnvFs),
        Box::new(ThreadSpawn),
    ]
}

/// Token-stream text of any AST node, with single spaces between tokens
/// (e.g. `v . sort_by (| a , b | ...)`). Span joins can fail across
/// files, so exemption matching works on this canonical text instead of
/// raw source slices.
fn tok(node: &impl ToTokens) -> String {
    node.to_token_stream().to_string()
}

/// (1-based line, 1-based column) of a span start.
fn lc(span: Span) -> (usize, usize) {
    let start = span.start();
    (start.line, start.column + 1)
}

/// Whether an attribute list marks test-only code: `#[test]` or a
/// `#[cfg(...)]` whose arguments mention `test`.
fn is_cfg_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        if a.path().is_ident("test") {
            return true;
        }
        a.path().is_ident("cfg") && tok(&a.meta).contains("test")
    })
}

/// Visitor overrides that stop recursion at test-only scopes:
/// `#[cfg(test)]` modules, `#[test]`/`#[cfg(test)]` free functions, and
/// `#[cfg(test)]` methods inside regular impl blocks.
macro_rules! skip_test_scopes {
    () => {
        fn visit_item_mod(&mut self, m: &'ast syn::ItemMod) {
            if is_cfg_test(&m.attrs) {
                return;
            }
            visit::visit_item_mod(self, m);
        }

        fn visit_item_fn(&mut self, f: &'ast syn::ItemFn) {
            if is_cfg_test(&f.attrs) {
                return;
            }
            visit::visit_item_fn(self, f);
        }

        fn visit_impl_item_fn(&mut self, f: &'ast syn::ImplItemFn) {
            if is_cfg_test(&f.attrs) {
                return;
            }
            visit::visit_impl_item_fn(self, f);
        }
    };
}

/// D1 `wall-clock`: `Instant::now` / `SystemTime::now` only behind the
/// `WallClock` seam in `server/real.rs` (plus measurement-only files).
struct WallClock;

struct WallClockVisitor {
    diags: Vec<RawDiag>,
}

impl<'ast> Visit<'ast> for WallClockVisitor {
    skip_test_scopes!();

    fn visit_expr_path(&mut self, p: &'ast syn::ExprPath) {
        let segs: Vec<String> = p.path.segments.iter().map(|s| s.ident.to_string()).collect();
        if segs.len() >= 2
            && segs[segs.len() - 1] == "now"
            && matches!(segs[segs.len() - 2].as_str(), "Instant" | "SystemTime")
        {
            let (line, col) = lc(p.path.segments.first().map(|s| s.ident.span()).unwrap_or_else(Span::call_site));
            self.diags.push(RawDiag {
                line,
                col,
                message: format!(
                    "`{}::now` outside the WallClock seam — route real time through \
                     `server::real::WallClock` so simulated runs stay deterministic",
                    segs[segs.len() - 2]
                ),
            });
        }
        visit::visit_expr_path(self, p);
    }
}

impl Rule for WallClock {
    fn id(&self) -> &'static str {
        "wall-clock"
    }
    fn description(&self) -> &'static str {
        "Instant::now/SystemTime::now only inside the WallClock seam (server/real.rs)"
    }
    fn applies_to(&self, rel: &str) -> bool {
        rel != "server/real.rs" && rel != "figures/overhead.rs" && !rel.starts_with("bench/")
    }
    fn check(&self, ctx: &FileCtx) -> Vec<RawDiag> {
        let mut v = WallClockVisitor { diags: Vec::new() };
        v.visit_file(ctx.ast);
        v.diags
    }
}

/// D2 `unordered-iter`: no iteration over `HashMap`/`HashSet` contents
/// unless the result is immediately sorted or folded into an
/// order-insensitive scalar.
struct UnorderedIter;

/// Pass A: every identifier (local, field, static, fn param) whose
/// declared type or initializer tokens mention HashMap/HashSet.
struct HashNameCollector {
    names: Vec<String>,
}

impl HashNameCollector {
    fn note(&mut self, name: String, type_text: &str) {
        if type_text.contains("HashMap") || type_text.contains("HashSet") {
            self.names.push(name);
        }
    }
}

impl<'ast> Visit<'ast> for HashNameCollector {
    fn visit_field(&mut self, f: &'ast syn::Field) {
        if let Some(id) = &f.ident {
            self.note(id.to_string(), &tok(&f.ty));
        }
        visit::visit_field(self, f);
    }

    fn visit_local(&mut self, l: &'ast syn::Local) {
        let name = match &l.pat {
            syn::Pat::Ident(p) => Some(p.ident.to_string()),
            syn::Pat::Type(t) => match &*t.pat {
                syn::Pat::Ident(p) => Some(p.ident.to_string()),
                _ => None,
            },
            _ => None,
        };
        if let Some(name) = name {
            self.note(name, &tok(l));
        }
        visit::visit_local(self, l);
    }

    fn visit_item_static(&mut self, s: &'ast syn::ItemStatic) {
        self.note(s.ident.to_string(), &tok(&s.ty));
        visit::visit_item_static(self, s);
    }

    fn visit_pat_type(&mut self, p: &'ast syn::PatType) {
        if let syn::Pat::Ident(id) = &*p.pat {
            self.note(id.ident.to_string(), &tok(&p.ty));
        }
        visit::visit_pat_type(self, p);
    }
}

/// Base identifier an expression reads from: `m` for `m`, `self.m`,
/// `(&m)`, `&mut m`. `None` when the receiver is itself a call result.
fn base_name(e: &syn::Expr) -> Option<String> {
    match e {
        syn::Expr::Path(p) => p.path.segments.last().map(|s| s.ident.to_string()),
        syn::Expr::Field(f) => match &f.member {
            syn::Member::Named(id) => Some(id.to_string()),
            syn::Member::Unnamed(_) => None,
        },
        syn::Expr::Reference(r) => base_name(&r.expr),
        syn::Expr::Paren(p) => base_name(&p.expr),
        _ => None,
    }
}

const ITER_METHODS: &[&str] =
    &["iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "drain"];

/// Spaced-token fragments that make a statement order-insensitive: the
/// iteration collapses to a scalar or is explicitly sorted.
const ORDER_OK: &[&str] = &[
    ". sort",
    ". sum ()",
    ". sum :: <",
    ". count ()",
    ". min ()",
    ". max ()",
    ". min_by",
    ". max_by",
    ". any (",
    ". all (",
    ". fold (",
];

fn stmt_is_order_ok(text: &str) -> bool {
    ORDER_OK.iter().any(|p| text.contains(p))
}

/// Pass B: walk statements, flagging hash-container iteration unless the
/// statement itself sorts/folds, or it binds a `let` whose very next
/// statement sorts the binding.
struct UnorderedIterVisitor<'n> {
    hash_names: &'n [String],
    diags: Vec<RawDiag>,
}

impl UnorderedIterVisitor<'_> {
    fn is_hash(&self, name: &str) -> bool {
        self.hash_names.iter().any(|n| n == name)
    }

    /// Findings inside one statement (spans of flagged expressions).
    fn scan_stmt(&self, stmt: &syn::Stmt) -> Vec<(usize, usize, String)> {
        struct Finder<'a> {
            outer: &'a UnorderedIterVisitor<'a>,
            found: Vec<(usize, usize, String)>,
        }
        impl<'a, 'ast> Visit<'ast> for Finder<'a> {
            // Nested blocks are scanned as their own statement lists by
            // the outer visitor; recursing here would double-report.
            fn visit_block(&mut self, _b: &'ast syn::Block) {}

            fn visit_expr_method_call(&mut self, c: &'ast syn::ExprMethodCall) {
                let m = c.method.to_string();
                if ITER_METHODS.contains(&m.as_str()) {
                    if let Some(recv) = base_name(&c.receiver) {
                        if self.outer.is_hash(&recv) {
                            let (line, col) = lc(c.method.span());
                            self.found.push((line, col, format!("`{recv}.{m}()`")));
                        }
                    }
                }
                visit::visit_expr_method_call(self, c);
            }

            fn visit_expr_for_loop(&mut self, f: &'ast syn::ExprForLoop) {
                if let Some(name) = base_name(&f.expr) {
                    if self.outer.is_hash(&name) {
                        let (line, col) = lc(f.for_token.span);
                        self.found.push((line, col, format!("`for _ in {name}`")));
                    }
                }
                visit::visit_expr_for_loop(self, f);
            }
        }
        let mut f = Finder { outer: self, found: Vec::new() };
        f.visit_stmt(stmt);
        f.found
    }
}

/// Name bound by `let <name> = ...;`, if the pattern is simple.
fn let_binding(stmt: &syn::Stmt) -> Option<String> {
    if let syn::Stmt::Local(l) = stmt {
        return match &l.pat {
            syn::Pat::Ident(p) => Some(p.ident.to_string()),
            syn::Pat::Type(t) => match &*t.pat {
                syn::Pat::Ident(p) => Some(p.ident.to_string()),
                _ => None,
            },
            _ => None,
        };
    }
    None
}

impl<'ast> Visit<'ast> for UnorderedIterVisitor<'_> {
    skip_test_scopes!();

    fn visit_block(&mut self, b: &'ast syn::Block) {
        for (i, stmt) in b.stmts.iter().enumerate() {
            let found = self.scan_stmt(stmt);
            if !found.is_empty() {
                let text = tok(stmt);
                let exempt = stmt_is_order_ok(&text)
                    || let_binding(stmt).is_some_and(|name| {
                        b.stmts.get(i + 1).is_some_and(|next| {
                            tok(next).contains(&format!("{name} . sort"))
                        })
                    });
                if !exempt {
                    for (line, col, what) in found {
                        self.diags.push(RawDiag {
                            line,
                            col,
                            message: format!(
                                "{what} iterates a hash-ordered container — collect and \
                                 sort, switch to BTreeMap/BTreeSet, or reduce to an \
                                 order-insensitive scalar"
                            ),
                        });
                    }
                }
            }
        }
        visit::visit_block(self, b);
    }
}

impl Rule for UnorderedIter {
    fn id(&self) -> &'static str {
        "unordered-iter"
    }
    fn description(&self) -> &'static str {
        "no HashMap/HashSet iteration unless immediately sorted or order-insensitive"
    }
    fn applies_to(&self, _rel: &str) -> bool {
        true
    }
    fn check(&self, ctx: &FileCtx) -> Vec<RawDiag> {
        let mut names = HashNameCollector { names: Vec::new() };
        names.visit_file(ctx.ast);
        let mut v = UnorderedIterVisitor { hash_names: &names.names, diags: Vec::new() };
        v.visit_file(ctx.ast);
        v.diags
    }
}

/// D3 `float-ord`: comparisons on float keys must use `total_cmp`.
struct FloatOrd;

struct FloatOrdVisitor {
    diags: Vec<RawDiag>,
}

impl<'ast> Visit<'ast> for FloatOrdVisitor {
    skip_test_scopes!();

    fn visit_expr_method_call(&mut self, c: &'ast syn::ExprMethodCall) {
        if c.method == "partial_cmp" {
            let (line, col) = lc(c.method.span());
            self.diags.push(RawDiag {
                line,
                col,
                message: "`partial_cmp` on a sort key — NaN makes it non-total and the \
                          comparator panics or reorders; use `total_cmp`"
                    .to_string(),
            });
        }
        visit::visit_expr_method_call(self, c);
    }
}

impl Rule for FloatOrd {
    fn id(&self) -> &'static str {
        "float-ord"
    }
    fn description(&self) -> &'static str {
        "float comparisons use total_cmp, never partial_cmp"
    }
    fn applies_to(&self, _rel: &str) -> bool {
        true
    }
    fn check(&self, ctx: &FileCtx) -> Vec<RawDiag> {
        let mut v = FloatOrdVisitor { diags: Vec::new() };
        v.visit_file(ctx.ast);
        v.diags
    }
}

/// D4 `seeded-rand`: no ambient randomness — no `rand`, `getrandom`,
/// `thread_rng`, `RandomState`, or `DefaultHasher`.
struct SeededRand;

const RAND_IDENTS: &[&str] =
    &["rand", "thread_rng", "RandomState", "DefaultHasher", "getrandom"];

struct SeededRandVisitor {
    diags: Vec<RawDiag>,
}

impl<'ast> Visit<'ast> for SeededRandVisitor {
    skip_test_scopes!();

    fn visit_path(&mut self, p: &'ast syn::Path) {
        for seg in &p.segments {
            let name = seg.ident.to_string();
            if RAND_IDENTS.contains(&name.as_str()) {
                let (line, col) = lc(seg.ident.span());
                self.diags.push(RawDiag {
                    line,
                    col,
                    message: format!(
                        "`{name}` introduces run-to-run nondeterminism — thread explicit \
                         seeds through `stats::rng::Rng` instead"
                    ),
                });
            }
        }
        visit::visit_path(self, p);
    }

    fn visit_item_use(&mut self, u: &'ast syn::ItemUse) {
        let text = tok(&u.tree);
        for name in ["rand", "getrandom"] {
            if text == name || text.starts_with(&format!("{name} ::")) {
                let (line, col) = lc(u.use_token.span);
                self.diags.push(RawDiag {
                    line,
                    col,
                    message: format!("importing `{name}` — the crate bans ambient randomness"),
                });
            }
        }
        visit::visit_item_use(self, u);
    }
}

impl Rule for SeededRand {
    fn id(&self) -> &'static str {
        "seeded-rand"
    }
    fn description(&self) -> &'static str {
        "no rand/getrandom/thread_rng/RandomState/DefaultHasher — explicit seeds only"
    }
    fn applies_to(&self, _rel: &str) -> bool {
        true
    }
    fn check(&self, ctx: &FileCtx) -> Vec<RawDiag> {
        let mut v = SeededRandVisitor { diags: Vec::new() };
        v.visit_file(ctx.ast);
        v.diags
    }
}

/// D5 `unbounded-log`: coordinator log fields must be `RingLog`, not
/// `Vec` — long-lived coordinators otherwise grow without bound.
struct UnboundedLog;

impl Rule for UnboundedLog {
    fn id(&self) -> &'static str {
        "unbounded-log"
    }
    fn description(&self) -> &'static str {
        "coordinator log fields use util::ring::RingLog, not unbounded Vec"
    }
    fn applies_to(&self, rel: &str) -> bool {
        rel == "server/coordinator.rs"
    }
    fn check(&self, ctx: &FileCtx) -> Vec<RawDiag> {
        struct V {
            diags: Vec<RawDiag>,
        }
        impl<'ast> Visit<'ast> for V {
            fn visit_item_struct(&mut self, s: &'ast syn::ItemStruct) {
                if !s.ident.to_string().contains("Coordinator") {
                    return;
                }
                for f in &s.fields {
                    let Some(id) = &f.ident else { continue };
                    let name = id.to_string();
                    if (name == "log" || name.ends_with("_log")) && tok(&f.ty).contains("Vec <") {
                        let (line, col) = lc(id.span());
                        self.diags.push(RawDiag {
                            line,
                            col,
                            message: format!(
                                "coordinator log field `{name}` is an unbounded Vec — use \
                                 `util::ring::RingLog` so long-lived runs stay bounded"
                            ),
                        });
                    }
                }
            }
        }
        let mut v = V { diags: Vec::new() };
        v.visit_file(ctx.ast);
        v.diags
    }
}

/// D6 `hot-path-panic`: no `unwrap`/`expect` in the serving hot paths
/// (`server/`, `lb/`, `dispatch/`).
struct HotPathPanic;

struct HotPathPanicVisitor {
    diags: Vec<RawDiag>,
}

impl<'ast> Visit<'ast> for HotPathPanicVisitor {
    skip_test_scopes!();

    fn visit_expr_method_call(&mut self, c: &'ast syn::ExprMethodCall) {
        let m = c.method.to_string();
        if m == "unwrap" || m == "expect" {
            let (line, col) = lc(c.method.span());
            self.diags.push(RawDiag {
                line,
                col,
                message: format!(
                    "`{m}` on a serving hot path — a poisoned lock or absent entry must \
                     degrade, not abort the coordinator; return an error or handle the None"
                ),
            });
        }
        visit::visit_expr_method_call(self, c);
    }
}

impl Rule for HotPathPanic {
    fn id(&self) -> &'static str {
        "hot-path-panic"
    }
    fn description(&self) -> &'static str {
        "no unwrap/expect in server/, lb/, dispatch/ non-test code"
    }
    fn applies_to(&self, rel: &str) -> bool {
        rel.starts_with("server/") || rel.starts_with("lb/") || rel.starts_with("dispatch/")
    }
    fn check(&self, ctx: &FileCtx) -> Vec<RawDiag> {
        let mut v = HotPathPanicVisitor { diags: Vec::new() };
        v.visit_file(ctx.ast);
        v.diags
    }
}

/// D7 `missing-docs`: every public item in the stable surfaces
/// (`workload/trace.rs`, `metrics/`) carries rustdoc.
struct MissingDocs;

fn has_doc(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| a.path().is_ident("doc"))
}

fn is_pub(vis: &syn::Visibility) -> bool {
    matches!(vis, syn::Visibility::Public(_))
}

struct MissingDocsVisitor {
    diags: Vec<RawDiag>,
}

impl MissingDocsVisitor {
    fn require(&mut self, kind: &str, ident: &syn::Ident, attrs: &[syn::Attribute]) {
        if !has_doc(attrs) {
            let (line, col) = lc(ident.span());
            self.diags.push(RawDiag {
                line,
                col,
                message: format!(
                    "public {kind} `{ident}` has no rustdoc — this file is a stable \
                     surface; document behavior and units"
                ),
            });
        }
    }
}

impl<'ast> Visit<'ast> for MissingDocsVisitor {
    fn visit_item_mod(&mut self, m: &'ast syn::ItemMod) {
        if is_cfg_test(&m.attrs) {
            return;
        }
        visit::visit_item_mod(self, m);
    }

    fn visit_item_fn(&mut self, f: &'ast syn::ItemFn) {
        if is_cfg_test(&f.attrs) {
            return;
        }
        if is_pub(&f.vis) {
            self.require("fn", &f.sig.ident, &f.attrs);
        }
        visit::visit_item_fn(self, f);
    }

    fn visit_item_struct(&mut self, s: &'ast syn::ItemStruct) {
        if is_pub(&s.vis) {
            self.require("struct", &s.ident, &s.attrs);
        }
        visit::visit_item_struct(self, s);
    }

    fn visit_item_enum(&mut self, e: &'ast syn::ItemEnum) {
        if is_pub(&e.vis) {
            self.require("enum", &e.ident, &e.attrs);
        }
        visit::visit_item_enum(self, e);
    }

    fn visit_item_trait(&mut self, t: &'ast syn::ItemTrait) {
        if is_pub(&t.vis) {
            self.require("trait", &t.ident, &t.attrs);
        }
        visit::visit_item_trait(self, t);
    }

    fn visit_item_type(&mut self, t: &'ast syn::ItemType) {
        if is_pub(&t.vis) {
            self.require("type alias", &t.ident, &t.attrs);
        }
        visit::visit_item_type(self, t);
    }

    fn visit_item_const(&mut self, c: &'ast syn::ItemConst) {
        if is_pub(&c.vis) {
            self.require("const", &c.ident, &c.attrs);
        }
        visit::visit_item_const(self, c);
    }

    fn visit_item_static(&mut self, s: &'ast syn::ItemStatic) {
        if is_pub(&s.vis) {
            self.require("static", &s.ident, &s.attrs);
        }
        visit::visit_item_static(self, s);
    }

    fn visit_item_impl(&mut self, i: &'ast syn::ItemImpl) {
        // Trait impls inherit docs from the trait definition.
        if i.trait_.is_some() {
            return;
        }
        for item in &i.items {
            if let syn::ImplItem::Fn(f) = item {
                if is_pub(&f.vis) && !is_cfg_test(&f.attrs) {
                    self.require("method", &f.sig.ident, &f.attrs);
                }
            }
        }
        visit::visit_item_impl(self, i);
    }
}

impl Rule for MissingDocs {
    fn id(&self) -> &'static str {
        "missing-docs"
    }
    fn description(&self) -> &'static str {
        "public items in workload/trace.rs and metrics/ carry rustdoc"
    }
    fn applies_to(&self, rel: &str) -> bool {
        rel == "workload/trace.rs" || rel.starts_with("metrics/")
    }
    fn check(&self, ctx: &FileCtx) -> Vec<RawDiag> {
        let mut v = MissingDocsVisitor { diags: Vec::new() };
        v.visit_file(ctx.ast);
        v.diags
    }
}

/// D8 `no-env-fs`: ambient process state (`std::env`, `std::fs`) is read
/// only at the edges — `cli/`, `config/`, `main.rs`.
struct NoEnvFs;

struct NoEnvFsVisitor {
    /// `use std::fs;` / `use std::env;` in scope, so bare `fs::...`
    /// paths count too.
    bare_imported: Vec<&'static str>,
    diags: Vec<RawDiag>,
}

impl<'ast> Visit<'ast> for NoEnvFsVisitor {
    skip_test_scopes!();

    fn visit_expr_path(&mut self, p: &'ast syn::ExprPath) {
        let segs: Vec<String> = p.path.segments.iter().map(|s| s.ident.to_string()).collect();
        let module = if segs.len() >= 2 && segs[0] == "std" && (segs[1] == "env" || segs[1] == "fs")
        {
            Some(segs[1].clone())
        } else if segs.len() >= 2 && self.bare_imported.iter().any(|m| *m == segs[0]) {
            Some(segs[0].clone())
        } else {
            None
        };
        if let Some(module) = module {
            let (line, col) = lc(p.path.segments.first().map(|s| s.ident.span()).unwrap_or_else(Span::call_site));
            self.diags.push(RawDiag {
                line,
                col,
                message: format!(
                    "`std::{module}` read outside the edges — ambient process state \
                     belongs in cli/ or config/; pass values in explicitly"
                ),
            });
        }
        visit::visit_expr_path(self, p);
    }
}

impl Rule for NoEnvFs {
    fn id(&self) -> &'static str {
        "no-env-fs"
    }
    fn description(&self) -> &'static str {
        "std::env/std::fs only in cli/, config/, main.rs"
    }
    fn applies_to(&self, rel: &str) -> bool {
        !(rel.starts_with("cli/") || rel.starts_with("config/") || rel == "main.rs")
    }
    fn check(&self, ctx: &FileCtx) -> Vec<RawDiag> {
        struct Uses {
            bare: Vec<&'static str>,
        }
        impl<'ast> Visit<'ast> for Uses {
            fn visit_item_use(&mut self, u: &'ast syn::ItemUse) {
                let text = tok(&u.tree);
                if text.starts_with("std :: fs") && !self.bare.contains(&"fs") {
                    self.bare.push("fs");
                }
                if text.starts_with("std :: env") && !self.bare.contains(&"env") {
                    self.bare.push("env");
                }
                visit::visit_item_use(self, u);
            }
        }
        let mut uses = Uses { bare: Vec::new() };
        uses.visit_file(ctx.ast);
        let mut v = NoEnvFsVisitor { bare_imported: uses.bare, diags: Vec::new() };
        v.visit_file(ctx.ast);
        v.diags
    }
}

/// D9 `thread-spawn`: ad-hoc threads are banned — all parallelism goes
/// through the scoped worker pool in `server/pump_pool.rs`, whose
/// score-in-parallel / commit-in-order protocol keeps dispatch decisions
/// bit-identical at every thread count.
struct ThreadSpawn;

struct ThreadSpawnVisitor {
    diags: Vec<RawDiag>,
}

impl ThreadSpawnVisitor {
    fn flag(&mut self, span: Span, what: &str) {
        let (line, col) = lc(span);
        self.diags.push(RawDiag {
            line,
            col,
            message: format!(
                "{what} outside the pump worker pool — ad-hoc threads make dispatch \
                 order racy; route parallelism through `server::pump_pool::run_parallel` \
                 (score-in-parallel, commit-in-order)"
            ),
        });
    }
}

impl<'ast> Visit<'ast> for ThreadSpawnVisitor {
    skip_test_scopes!();

    fn visit_path(&mut self, p: &'ast syn::Path) {
        let segs: Vec<String> = p.segments.iter().map(|s| s.ident.to_string()).collect();
        for w in segs.windows(2) {
            if w[0] == "thread" && matches!(w[1].as_str(), "spawn" | "scope" | "Builder") {
                self.flag(
                    p.segments.first().map(|s| s.ident.span()).unwrap_or_else(Span::call_site),
                    &format!("`thread::{}`", w[1]),
                );
            }
        }
        visit::visit_path(self, p);
    }

    fn visit_expr_method_call(&mut self, c: &'ast syn::ExprMethodCall) {
        // `scope.spawn(..)` / `Builder::new().spawn(..)`: any spawn method
        // call counts — the only legitimate receiver lives in the exempt
        // pool module itself.
        if c.method == "spawn" {
            self.flag(c.method.span(), "`.spawn(..)`");
        }
        visit::visit_expr_method_call(self, c);
    }
}

impl Rule for ThreadSpawn {
    fn id(&self) -> &'static str {
        "thread-spawn"
    }
    fn description(&self) -> &'static str {
        "thread spawns only inside server/pump_pool.rs (the deterministic pump pool)"
    }
    fn applies_to(&self, rel: &str) -> bool {
        rel != "server/pump_pool.rs"
    }
    fn check(&self, ctx: &FileCtx) -> Vec<RawDiag> {
        let mut v = ThreadSpawnVisitor { diags: Vec::new() };
        v.visit_file(ctx.ast);
        v.diags
    }
}
