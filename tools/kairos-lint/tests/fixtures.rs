//! Fixture-driven rule tests.
//!
//! Each rule id has `tests/fixtures/<id>/bad/` (a miniature source tree
//! that must trip exactly that rule) and `tests/fixtures/<id>/ok/` (the
//! corrected tree, which must be clean under ALL rules). The directory
//! layout below `bad`/`ok` mirrors real `rust/src` paths, so path-scoped
//! rules are exercised with realistic `rel` values.

use std::path::{Path, PathBuf};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn rule_ids() -> Vec<&'static str> {
    let mut ids: Vec<&'static str> =
        kairos_lint::default_rules().iter().map(|r| r.id()).collect();
    ids.push(kairos_lint::SUPPRESSION_RULE);
    ids
}

#[test]
fn every_rule_has_a_firing_bad_fixture() {
    let rules = kairos_lint::default_rules();
    for id in rule_ids() {
        let bad = fixture_root().join(id).join("bad");
        let diags = kairos_lint::lint_root(&bad, &rules)
            .unwrap_or_else(|e| panic!("linting {id}/bad: {e}"));
        assert!(
            diags.iter().any(|d| d.rule == id),
            "fixture {id}/bad must trip rule `{id}`, got: {diags:#?}"
        );
    }
}

#[test]
fn every_rule_has_a_clean_ok_fixture() {
    let rules = kairos_lint::default_rules();
    for id in rule_ids() {
        let ok = fixture_root().join(id).join("ok");
        let diags = kairos_lint::lint_root(&ok, &rules)
            .unwrap_or_else(|e| panic!("linting {id}/ok: {e}"));
        assert!(
            diags.is_empty(),
            "fixture {id}/ok must be clean under every rule, got: {diags:#?}"
        );
    }
}

#[test]
fn no_fixture_dir_lacks_a_registered_rule() {
    let ids = rule_ids();
    for entry in std::fs::read_dir(fixture_root()).expect("fixtures dir") {
        let name = entry.expect("dir entry").file_name();
        let name = name.to_string_lossy();
        assert!(
            ids.iter().any(|id| *id == name),
            "fixture dir `{name}` has no registered rule — stale fixture or renamed id"
        );
    }
}

#[test]
fn suppression_round_trip() {
    let rules = kairos_lint::default_rules();
    // With a reason: the violation is waived, nothing else fires.
    let with_reason = kairos_lint::lint_root(
        &fixture_root().join("suppression/ok"),
        &rules,
    )
    .expect("lint suppression/ok");
    assert!(with_reason.is_empty(), "{with_reason:#?}");

    // Without a reason: the marker itself errors AND the underlying
    // violation still fires — a broken allow must never waive anything.
    let without_reason = kairos_lint::lint_root(
        &fixture_root().join("suppression/bad"),
        &rules,
    )
    .expect("lint suppression/bad");
    assert!(
        without_reason.iter().any(|d| d.rule == kairos_lint::SUPPRESSION_RULE),
        "{without_reason:#?}"
    );
    assert!(
        without_reason.iter().any(|d| d.rule == "wall-clock"),
        "{without_reason:#?}"
    );
}
