pub struct Sketch {
    centers: Vec<f64>,
}

pub fn width(s: &Sketch) -> usize {
    s.centers.len()
}
