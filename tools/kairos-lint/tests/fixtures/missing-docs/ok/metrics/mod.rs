/// Fixed-width quantile sketch over latency samples.
pub struct Sketch {
    centers: Vec<f64>,
}

/// Number of centroids currently held.
pub fn width(s: &Sketch) -> usize {
    s.centers.len()
}
