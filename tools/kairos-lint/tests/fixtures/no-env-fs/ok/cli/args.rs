pub fn load(path: &str) -> String {
    std::fs::read_to_string(path).unwrap_or_default()
}
