pub fn hasher() -> std::collections::hash_map::RandomState {
    std::collections::hash_map::RandomState::new()
}
