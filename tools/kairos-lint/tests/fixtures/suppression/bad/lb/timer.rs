pub fn stamp() -> std::time::Instant {
    // kairos-lint: allow(wall-clock)
    std::time::Instant::now()
}
