pub fn stamp() -> std::time::Instant {
    // kairos-lint: allow(wall-clock, fixture demonstrating a reasoned waiver)
    std::time::Instant::now()
}
