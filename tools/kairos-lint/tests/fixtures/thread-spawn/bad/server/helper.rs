pub fn score_on_the_side(xs: &[u64]) -> u64 {
    let owned: Vec<u64> = xs.to_vec();
    let h = std::thread::spawn(move || owned.iter().sum::<u64>());
    match h.join() {
        Ok(v) => v,
        Err(_) => 0,
    }
}
