pub fn run_parallel<J: Sync, R: Send, F: Fn(&J) -> R + Sync>(
    jobs: &[J],
    f: F,
) -> Vec<R> {
    let mut out = Vec::with_capacity(jobs.len());
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(jobs.len());
        for j in jobs {
            handles.push(scope.spawn(|| f(j)));
        }
        for h in handles {
            match h.join() {
                Ok(r) => out.push(r),
                Err(p) => std::panic::resume_unwind(p),
            }
        }
    });
    out
}
