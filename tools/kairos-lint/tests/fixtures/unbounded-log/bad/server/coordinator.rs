pub struct Coordinator {
    pub scale_log: Vec<String>,
}
