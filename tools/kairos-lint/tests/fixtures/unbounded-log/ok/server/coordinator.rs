pub struct RingLog<T> {
    items: Vec<T>,
    cap: usize,
}

pub struct Coordinator {
    pub scale_log: RingLog<String>,
}
