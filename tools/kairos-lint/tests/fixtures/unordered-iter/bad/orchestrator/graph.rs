use std::collections::HashMap;

pub fn first_key(m: &HashMap<String, u64>) -> Option<&String> {
    for k in m.keys() {
        return Some(k);
    }
    None
}
