use std::collections::HashMap;

pub fn sorted_keys(m: &HashMap<String, u64>) -> Vec<String> {
    let mut keys: Vec<String> = m.keys().cloned().collect();
    keys.sort();
    keys
}

pub fn total(m: &HashMap<String, u64>) -> u64 {
    m.values().sum()
}
