pub fn tick_ns() -> u128 {
    let t = std::time::Instant::now();
    t.elapsed().as_nanos()
}
