pub struct WallClock;

impl WallClock {
    pub fn now_ns() -> u128 {
        std::time::Instant::now().elapsed().as_nanos()
    }
}
